"""End-to-end training driver: train an LM for a few hundred steps with the
full production loop — prefetching data pipeline, AdamW + warmup schedule,
remat, checkpointing every 25 steps, straggler monitoring, and auto-resume
(kill it mid-run and start again: it continues from the latest checkpoint).

Default is a reduced xlstm-125m-family config sized for CPU;
``--arch gemma2-2b --no-reduced`` runs the real config (TPU-scale).

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_arch, reduced
from repro.data import Prefetcher, lm_batches
from repro.models import build_model
from repro.training import CheckpointManager, init_train_state, make_train_step
from repro.training.fault import StragglerMonitor

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="xlstm-125m")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
ap.add_argument("--no-reduced", action="store_true")
args = ap.parse_args()

cfg = get_arch(args.arch) if args.no_reduced else reduced(get_arch(args.arch))
model = build_model(cfg)
tc = TrainConfig(learning_rate=1e-3, warmup_steps=20, remat="dots")
print(f"arch={cfg.name} params={cfg.param_count() / 1e6:.1f}M")

ckpt = CheckpointManager(args.ckpt_dir, keep=2)
state = init_train_state(model, tc, jax.random.PRNGKey(0))
start = 0
if ckpt.latest_step() is not None:
    state, start = ckpt.restore(jax.eval_shape(lambda: state))
    print(f"resumed from checkpoint at step {start}")

step_fn = jax.jit(make_train_step(model, tc))
mon = StragglerMonitor(threshold=4.0)
data = Prefetcher(lm_batches(cfg.vocab, args.batch, args.seq,
                             args.steps, seed=0), depth=2)

t0 = time.time()
for i, b in enumerate(data):
    if i < start:
        continue
    ts = time.time()
    state, metrics = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
    mon.record(i, time.time() - ts)
    if (i + 1) % 25 == 0:
        ckpt.save_async(i + 1, state)
        print(f"step {i + 1:4d} loss={float(metrics['loss']):.4f} "
              f"lr={float(metrics['lr']):.2e} "
              f"gnorm={float(metrics['grad_norm']):.2f}")
ckpt.wait()
ckpt.save(args.steps, state)
toks = (args.steps - start) * args.batch * args.seq
print(f"done: {toks / (time.time() - t0):.0f} tokens/s on CPU, "
      f"{len(mon.stragglers)} straggler steps, final loss "
      f"{float(metrics['loss']):.4f}")
