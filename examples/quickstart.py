"""Quickstart: the whole Peregrine loop in ~40 lines.

Synthesises a Mirai-style trace, trains the detector on the benign prefix,
then streams the attack window through the data-plane feature pipeline and
scores per-epoch records — §3.2's workflow end to end.  The service's
``observe_stream``/``process_stream`` chunk the trace with bounded memory
and carry flow-table state plus the global packet count across chunks.

  PYTHONPATH=src python examples/quickstart.py

Swap the FC data plane by name, e.g. the hash-partitioned flow tables:
``DetectionService(..., backend="sharded", shards=16)`` — and the MD
scoring stage the same way: ``DetectionService(..., md_backend="pallas")``
runs KitNET's ensemble layer through the fused Pallas kernel, with each
chunk's records scored as they arrive (per-chunk streaming scores are
bit-identical to one-batch for the serial-semantics FC backends).
"""
from repro.detection.metrics import auc
from repro.serving import DetectionService
from repro.traffic import synth_trace

# 1. a trace: benign training prefix + eval window with the attack mixed in
data = synth_trace("mirai", n_train=12000, n_benign_eval=6000,
                   n_attack=6000, seed=0)

# 2. the detector: per-packet FC in the (TPU) data plane, one feature record
#    every 256 packets to the KitNET classifier — sampling AFTER features.
svc = DetectionService(epoch=256, n_slots=8192, mode="exact")

# 3. training phase: benign traffic only (first 1M packets in the paper)
svc.observe_stream(data["train"], chunk=4096)
svc.fit(fpr=0.01)
print(f"trained; alarm threshold RMSE={svc.threshold:.4f}")

# 4. detection phase: stream the eval window. Record indices are global
#    stream positions, so subtract the eval window's start offset to look up
#    labels — chunking does not change which packets close an epoch.
eval_start = svc.pkt_count
idx, scores, alarms = svc.process_stream(data["eval"], chunk=4096)
labels = data["eval"]["label"][idx - eval_start]

print(f"{len(scores)} records scored, {int(alarms.sum())} alarms")
print(f"attack-record AUC = {auc(scores, labels):.3f}  "
      f"(paper: >0.8 for 13/15 attacks)")
