"""Bucketed mesh-parallel FC engine — shard count as a throughput axis.

``core/sharded.py`` partitions the *flow tables* and replays the serial
oracle inside each shard: every shard still walks the full packet batch, so
one host pays ~S× the serial work and adding shards *lowers* single-host
throughput (BENCH_throughput.json).  This module partitions the *packets*
instead, on top of the segmented-scan pipeline (``core/parallel.py``):

1. **Compaction.**  The batch is stably sorted by flow hash — the argsort
   by slot the scan backend already pays, no new sort primitives.  Flow
   slots ARE hashes (core/state.py), so the sorted order is a flow-hash
   compaction: every stream is a contiguous run.
2. **Bucketing.**  The compacted batch is cut into S equal slices (a free
   ``(n,) -> (S, n/S)`` reshape).  Buckets are *perfectly balanced by
   construction* — heavy-hitter flows cannot skew them, unlike a
   slot-modulo partition whose worst-case bucket is the whole batch.  The
   price is that at most S-1 streams straddle a cut.
3. **Per-bucket scans.**  Each bucket runs the segmented atom/latest-value
   scans independently (depth O(log n/S) instead of O(log n)); an O(S)
   exclusive combine over per-bucket tail summaries carries the straddling
   streams — the same associative operator, reassociated (results match
   the flat ``scan`` backend to a few ulp; bit-identical at S=1; the
   serial-oracle parity suite holds it to the scan backend's tolerance).
4. **Scatter-back.**  Results return to original packet order through the
   one shared inverse permutation (``core/arith.invert_perm``), exactly as
   the flat scan does.

Placement: on one device the bucket axis is a vectorised batch dimension.
When a mesh is bound and the ``flow_shards`` logical axis has a rule
(distributed/sharding.py), the WHOLE two-level scan runs under
``shard_map`` over that axis (``ShardContext``): each device scans only
its buckets, all-gathers the O(S) per-bucket tail summaries — the only
collective, a few KB — runs the tiny cross-bucket combine redundantly,
and fixes up its own buckets locally.  No O(n) step ever crosses a shard
boundary (DESIGN.md §12).  Ragged batches are padded to a bucket multiple
with sentinel-slot packets that never store back and are never emitted.

``process_bucketed_sampled`` is the record-sampled twin for the fused
serving step (DESIGN.md §8/§9), registered in ``core/backends`` so a
``backend="bucketed"`` service gets the device-resident fast path for free.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax

from repro.core.parallel import _process_parallel_impl
from repro.distributed.sharding import (
    ShardContext, ambient_mesh, flow_shards_binding,
)


def _resolve_placement(buckets: int):
    """(mesh, binding) for shard_map over the bucket axis, or (None, None).

    Resolved OUTSIDE jit (like core/sharded.py) so the ambient mesh/rule
    participates in the jit cache key — toggling ``use_rules`` retraces
    instead of silently reusing an executable compiled under a different
    placement.  Falls back to single-device vectorisation when no mesh is
    bound, the ``flow_shards`` rule is unbound, the mesh lacks the bound
    axes, or the bucket count does not divide over the axis size.
    """
    binding = flow_shards_binding()
    if binding is None:
        return None, None
    mesh = ambient_mesh()
    if mesh is None:
        return None, None
    axes = binding if isinstance(binding, tuple) else (binding,)
    if not all(a in mesh.axis_names for a in axes):
        return None, None
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if size < 1 or buckets % size:
        return None, None
    return mesh, binding


@functools.lru_cache(maxsize=None)
def _shard_ctx(mesh, binding, n_devices: int):
    """The ``ShardContext`` placing the two-level scans on ``mesh``, or
    ``None`` when unplaced (the bucket axis then stays a plain vectorised
    batch dimension on one device).  Cached per (mesh, binding, device
    count) so repeated calls under one placement share one context — and
    therefore one jit cache entry.  ``n_devices`` is in the key explicitly
    (on top of ``Mesh.__hash__``, which already folds in its devices) so a
    re-bound mesh under a different forced-device topology can never be
    served a stale compiled step.
    """
    if mesh is None:
        return None
    return ShardContext(mesh, binding)


@functools.lru_cache(maxsize=None)
def _bucketed_jit(buckets: int, shard, n_devices: int):
    @jax.jit
    def run(state, pkts):
        return _process_parallel_impl(state, pkts, chunks=buckets,
                                      shard=shard)

    return run


def process_bucketed(state: Dict, pkts: Dict[str, jax.Array],
                     buckets: int = 4, mode: str = "exact"
                     ) -> Tuple[Dict, jax.Array]:
    """Bucketed data-parallel FC: same I/O as ``process_parallel``, the
    batch cut into ``buckets`` balanced flow-hash buckets scanned in
    parallel.  Exact arithmetic only — ``switch`` mode raises; pick the
    ``serial``/``sharded`` oracle backends for the approximated
    arithmetic (they are the only packet-serial paths)."""
    if buckets < 1:
        raise ValueError(f"buckets must be >= 1, got {buckets}")
    if mode != "exact":
        raise ValueError("bucketed backend is exact-mode only")
    mesh, binding = _resolve_placement(buckets)
    ndev = jax.device_count()
    shard = _shard_ctx(mesh, binding, ndev)
    return _bucketed_jit(buckets, shard, ndev)(state, pkts)


def process_bucketed_sampled(state: Dict, pkts: Dict[str, jax.Array],
                             sample_idx: jax.Array, buckets: int = 4
                             ) -> Tuple[Dict, jax.Array]:
    """Record-sampled bucketed FC for the fused serving step: state update
    covers every packet, feature rows materialise only at ``sample_idx``
    (row-for-row identical to slicing the full output).  Unjitted — the
    caller (serving/fused.py) inlines it into its own donated jit; the
    ambient placement is resolved at trace time."""
    mesh, binding = _resolve_placement(buckets)
    shard = _shard_ctx(mesh, binding, jax.device_count())
    return _process_parallel_impl(state, pkts, sample_idx,
                                  chunks=buckets, shard=shard)
