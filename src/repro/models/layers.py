"""Shared neural-net building blocks (pure functions over param pytrees)."""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import lshard

Params = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, gain: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gain.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, gain: jax.Array, bias: jax.Array, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * gain.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True)}[name]


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    freqs = rope_freqs(x.shape[-1], theta)                     # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs     # (B, S, D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, ...]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, D); positions: (B, S, 3) [temporal, height, width] — the stub
    frontend supplies identical t/h/w positions for text-only cells, making
    this numerically identical to 1-D RoPE while exercising the real M-RoPE
    dataflow (sectioned frequency/position pairing).
    """
    import numpy as np
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                               # (D/2,)
    # Split D/2 frequency slots across the three position streams (static).
    assert sum(sections) == D // 2, (sections, D)
    stream = np.repeat(np.arange(3), np.asarray(sections))     # (D/2,)
    pos = jnp.take(positions.astype(jnp.float32), jnp.asarray(stream), axis=-1)
    ang = pos * freqs[None, None, :]                           # (B, S, D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU-style gated)
# ---------------------------------------------------------------------------
def mlp_init(key, d: int, d_ff: int, dtype, gated: bool = True) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wi": dense_init(k1, d, d_ff, dtype),
         "wo": dense_init(k3, d_ff, d, dtype)}
    if gated:
        p["wg"] = dense_init(k2, d, d_ff, dtype)
    return p


def mlp_fwd(p: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    # x: (B, S, d); gated (SwiGLU-style) when "wg" present, classic otherwise
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if "wg" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        h = act_fn(act)(g) * h
    else:
        h = act_fn(act)(h)
    h = lshard(h, "batch", "seq", "ff")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])
