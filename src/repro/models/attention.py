"""Attention: GQA with RoPE/M-RoPE, sliding window, logit softcap.

Three execution paths share one math definition:
  * ``dense_attention``     — materialises (S, S) scores; short sequences.
  * ``blockwise_attention`` — flash-style lax.scan over KV blocks; long
    sequences (prefill_32k) without O(S^2) memory.
  * ``decode_attention``    — one query step against a KV cache.

All paths are numerically equivalent (tested) and GQA-aware: q heads are
grouped as (K, G) so the kv tensors are never materialised repeated.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import lshard
from repro.models.layers import apply_mrope, apply_rope, dense_init, softcap

Params = Dict[str, jax.Array]

NEG_INF = -1e30
BLOCKWISE_THRESHOLD = 4096   # use blockwise path for S >= this
KV_BLOCK = 1024


def attn_init(key, cfg: ArchConfig, dtype) -> Params:
    d, hd = cfg.d_model, cfg.hd
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d, cfg.n_heads * hd, dtype),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ko, cfg.n_heads * hd, d, dtype),
    }


def qkv_proj(p: Params, x: jax.Array, cfg: ArchConfig,
             positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,K,hd), rope applied."""
    B, S, _ = x.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        pos1d = positions if positions.ndim == 2 else positions[..., 0]
        q = apply_rope(q, pos1d, cfg.rope_theta)
        k = apply_rope(k, pos1d, cfg.rope_theta)
    q = lshard(q, "batch", "seq", "heads", None)
    k = lshard(k, "batch", "seq", "kv_heads", None)
    v = lshard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window) -> jax.Array:
    """(…, Sq, Sk) additive bias. ``window`` may be a traced int32 scalar
    (per-layer windows threaded through lax.scan); 0 means full attention."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(d.shape, dtype=bool)
    if causal:
        ok &= d >= 0
    if isinstance(window, int):
        if window > 0:
            ok &= d < window
    else:
        weff = jnp.where(window > 0, window, jnp.int32(2 ** 30))
        ok &= d < weff
    return jnp.where(ok, 0.0, NEG_INF)


def _grouped(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, S, H, d) -> (B, S, K, G, d)."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, hd)


def dense_attention(q, k, v, cfg: ArchConfig, q_pos, k_pos,
                    causal: Optional[bool] = None, window: Optional[int] = None):
    """Full-score attention. q: (B,Sq,H,d), k/v: (B,Sk,K,d) -> (B,Sq,H,d)."""
    causal = cfg.causal if causal is None else causal
    window = cfg.window if window is None else window
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    qg = _grouped(q, K)                                   # (B,Sq,K,G,d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(hd)
    scores = softcap(scores, cfg.attn_softcap)
    bias = _mask_bias(q_pos, k_pos, causal, window)       # (B?,Sq,Sk)
    if bias.ndim == 2:
        bias = bias[None]
    scores = scores + bias[:, None, None, :, :]
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w.astype(v.dtype), v)
    return out.reshape(B, Sq, H, hd)


def blockwise_attention(q, k, v, cfg: ArchConfig, q_pos, k_pos,
                        causal: Optional[bool] = None,
                        window: Optional[int] = None,
                        kv_block: int = KV_BLOCK):
    """Flash-style streaming softmax over KV blocks (O(Sq * kv_block) memory).

    Numerically matches ``dense_attention`` (same fp32 softmax), used for
    long-sequence prefill where (Sq, Sk) scores would not fit.
    """
    causal = cfg.causal if causal is None else causal
    window = cfg.window if window is None else window
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    K = k.shape[2]
    nb = -(-Sk // kv_block)
    pad = nb * kv_block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2**30)
    # inputs stay in model dtype; dots ACCUMULATE in f32 via
    # preferred_element_type — avoids materialising fp32 copies of q/k/v and
    # the post-softmax p (§Perf gemma2 C2: -39% HBM bytes on the train cell)
    qg = _grouped(q, K)                                   # (B,Sq,K,G,d)
    scale = 1.0 / math.sqrt(hd)

    kb = k.reshape(B, nb, kv_block, K, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, kv_block, K, hd).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(B, nb, kv_block).transpose(1, 0, 2)

    def step(carry, blk):
        m, l, acc = carry
        kblk, vblk, pblk = blk
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kblk,
                       preferred_element_type=jnp.float32) * scale
        s = softcap(s, cfg.attn_softcap)
        bias = _mask_bias(q_pos, pblk, causal, window)    # (B,Sq,T)
        s = s + bias[:, None, None, :, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(v.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    G = H // K

    def run(kb, vb, pb):
        m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
        a0 = jnp.zeros((B, K, G, Sq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    # flash semantics: never keep the per-block score/probability tensors as
    # backward residuals — recompute them from q/k/v (jax.checkpoint).  On
    # the gemma2 train cell this removes ~2.5 TB/device of saved-residual
    # traffic per step for ~+12% attention recompute FLOPs (§Perf C3).
    out = jax.checkpoint(run)(kb, vb, pb)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def attention(q, k, v, cfg: ArchConfig, q_pos, k_pos,
              causal: Optional[bool] = None, window: Optional[int] = None):
    if q.shape[1] >= BLOCKWISE_THRESHOLD:
        return blockwise_attention(q, k, v, cfg, q_pos, k_pos, causal, window)
    return dense_attention(q, k, v, cfg, q_pos, k_pos, causal, window)


def decode_attention(q, k_cache, v_cache, cfg: ArchConfig, cache_len,
                     window: Optional[int] = None):
    """Single-step decode. q: (B,1,H,d); caches: (B,Smax,K,d); cache_len: (B,).

    Masks positions >= cache_len. The sequence axis of the cache may be
    sharded (long-context); this einsum form lets GSPMD lower it to a partial
    softmax + combine. An explicit shard_map LSE-combine variant lives in
    ``repro.distributed.seq_parallel``.
    """
    window = cfg.window if window is None else window
    B, _, H, hd = q.shape
    Smax, K = k_cache.shape[1], k_cache.shape[2]
    qg = _grouped(q, K).astype(jnp.float32)[:, 0]          # (B,K,G,d)
    s = jnp.einsum("bkgd,btkd->bkgt", qg,
                   k_cache.astype(jnp.float32)) / math.sqrt(hd)
    s = softcap(s, cfg.attn_softcap)
    t = jnp.arange(Smax)[None, :]
    ok = t < cache_len[:, None]
    if isinstance(window, int):
        if window > 0:
            ok &= t >= (cache_len[:, None] - window)
    else:
        weff = jnp.where(window > 0, window, jnp.int32(2 ** 30))
        ok &= t >= (cache_len[:, None] - weff)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", w, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def attn_out(p: Params, o: jax.Array) -> jax.Array:
    B, S, H, hd = o.shape
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, S, H * hd), p["wo"])
