"""Int8 gradient compression with error feedback (1-bit-Adam-style EF).

In a multi-host deployment the quantised tensors are what crosses the DCN:
the all-reduce runs over int8 payloads (4x less DCN traffic than fp32),
and the quantisation error is fed back into the next step's gradient so the
optimizer sees an unbiased long-run signal.

Under single-program SPMD the psum itself is inserted by GSPMD, so here we
model the *numerics* (quantise -> sum -> dequantise, plus error feedback);
the communication-volume saving is accounted analytically in the roofline's
collective term (benchmarks/roofline.py applies the 4x factor when
grad_compression is on).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_compress(grads: Dict, err: Dict) -> Tuple[Dict, Dict]:
    """Returns (dequantised grads to feed the optimizer, new error buffers)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq

    out = jax.tree_util.tree_map(one, grads, err)
    leaves, treedef = jax.tree_util.tree_flatten(
        out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2)
    deq = treedef.unflatten([l[0] for l in leaves])
    new_err = treedef.unflatten([l[1] for l in leaves])
    return deq, new_err
