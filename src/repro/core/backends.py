"""FC backend registry — one API, three interchangeable data planes.

Peregrine's architectural bet is that feature computation is the swappable,
throughput-critical stage (cf. Whisper's frequency-domain frontend and
flow-classification pipelines): the detector never cares *how* the 80
per-packet features were produced.  This module makes that explicit:

    new_state, feats = compute_features(state, pkts, backend="pallas")

Backends (all emit the identical (n, N_FEATURES) layout):

  * ``serial`` — the per-packet lax.scan oracle (core/pipeline.py).  The
    only backend that also supports ``mode="switch"`` (shift-approximated
    arithmetic + round-robin decay), which is inherently packet-serial.
  * ``scan``   — TPU-native segmented associative scans (core/parallel.py),
    O(log n) depth over a packet batch.  Exact mode only.
  * ``pallas`` — the full-feature Pallas kernel
    (kernels/feature_update.feature_update_full): the switch pipeline on a
    TPU core, flow tables resident in VMEM.  Exact mode only; runs in
    interpret mode on CPU and compiles on real TPU.
  * ``sharded`` — hash-partitioned flow tables (core/sharded.py): S shards
    executed in parallel (vmap / mesh placement via the ``flow_shards``
    logical axis), bit-identical to ``serial`` in both modes.  Select the
    partition count with ``shards=S``.  Its per-shard path is the packet-
    serial oracle, so it is the *switch-mode* partitioning story; for
    exact-mode throughput use ``bucketed``.
  * ``bucketed`` — bucketed data-parallel segmented scans
    (core/bucketed.py): the batch is flow-hash-compacted and cut into S
    balanced buckets scanned in parallel (``shard_map`` over the
    ``flow_shards`` mesh axis when bound).  Exact mode only; select the
    bucket count with ``buckets=S``.

``register_backend`` remains the extension point for further flow-table
backends (e.g. multi-host partitions).

State-backend dispatch: the five FC backends above all implement the
DENSE state contract (direct-indexed ``(n_slots, ...)`` tables).  A state
built with ``init_state(..., state_backend="sketch")`` carries its own
compute path (core/sketch.py); ``compute_features`` identifies it
structurally (``state_spec_of``) and routes there, with the ``backend=``
name demoted to an implementation hint (``pallas`` → the sketch Pallas
kernel, anything else → the pure-JAX reference).  ``"sketch"`` is also a
registered FC name so benchmark/CLI specs can spell it directly.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax

from repro.core.state import state_spec_of

# name -> (fn(state, pkts, mode, **kw) -> (state, feats), supported modes)
_REGISTRY: Dict[str, Tuple[Callable, Tuple[str, ...]]] = {}

# name -> fn(state, pkts, sample_idx, **kw) -> (state, feats[sample_idx]):
# backends that can emit ONLY the sampled feature rows (state update still
# covers every packet) — the fused serving step's fast path
_SAMPLED: Dict[str, Callable] = {}

# legacy / convenience spellings
_ALIASES = {"parallel": "scan", "oracle": "serial", "kernel": "pallas"}


def register_backend(name: str, modes: Tuple[str, ...] = ("exact",)):
    """Register ``fn(state, pkts, mode=..., **kw)`` as FC backend ``name``."""
    def deco(fn):
        _REGISTRY[name] = (fn, modes)
        return fn
    return deco


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_backend(name: str) -> str:
    """Canonical backend name (alias-aware); raises on unknown names."""
    name = _ALIASES.get(name, name)
    if name not in _REGISTRY:
        raise ValueError(f"unknown FC backend {name!r}; "
                         f"available: {available_backends()}")
    return name


@register_backend("serial", modes=("exact", "switch"))
def _serial(state, pkts, mode: str = "exact", **_kw):
    from repro.core.pipeline import process_serial
    return process_serial(state, pkts, mode=mode)


@register_backend("scan")
def _scan(state, pkts, mode: str = "exact", **_kw):
    from repro.core.parallel import process_parallel
    return process_parallel(state, pkts)


@register_backend("pallas")
def _pallas(state, pkts, mode: str = "exact", chunk: int = 256,
            interpret=None, **_kw):
    from repro.kernels import ops
    return ops.feature_update_full(state, pkts, chunk=chunk,
                                   interpret=interpret)


@register_backend("sharded", modes=("exact", "switch"))
def _sharded(state, pkts, mode: str = "exact", shards: int = 4, **_kw):
    from repro.core.sharded import process_sharded
    return process_sharded(state, pkts, shards=shards, mode=mode)


@register_backend("bucketed")
def _bucketed(state, pkts, mode: str = "exact", buckets: int = 4, **_kw):
    from repro.core.bucketed import process_bucketed
    return process_bucketed(state, pkts, buckets=buckets, mode=mode)


@register_backend("sketch")
def _sketch(state, pkts, mode: str = "exact", **kw):
    # only reachable with a non-sketch state (sketch states dispatch via
    # state_spec_of before the registry lookup)
    raise ValueError(
        "backend='sketch' needs sketch-backed state; build it with "
        "init_state(n_slots, state_backend='sketch', rows=R) — the state "
        f"passed here is {state_spec_of(state).name!r}")


def compute_features(state: Dict, pkts: Dict[str, jax.Array],
                     backend: str = "scan", mode: str = "exact",
                     **kw) -> Tuple[Dict, jax.Array]:
    """Run one packet batch through the selected FC backend.

    state: ``init_state`` dict; pkts: raw packet arrays.  Returns
    ``(new_state, feats (n, N_FEATURES))``.  Extra kwargs go to the backend
    (e.g. ``chunk=``/``interpret=`` for pallas).

    Donation contract: callers that wrap this in a donated jit (the fused
    serving step does, with ``state`` donated) must treat the passed-in
    state handle as consumed — continue from ``new_state`` only, and
    snapshot with ``tree_map(jnp.copy, state)`` beforehand if a restore
    point is needed (DESIGN.md §8).
    """
    name = resolve_backend(backend)
    spec = state_spec_of(state)
    if spec.compute is not None:
        # non-dense state carries its own compute path; the backend name
        # becomes an implementation hint (e.g. "pallas" -> sketch kernel)
        return spec.compute(state, pkts, mode=mode, fc_backend=name, **kw)
    fn, modes = _REGISTRY[name]
    if mode not in modes:
        raise ValueError(
            f"FC backend {name!r} does not support mode {mode!r} "
            f"(supports {modes}); use backend='serial' or 'sharded' "
            "for switch mode")
    return fn(state, pkts, mode=mode, **kw)


def register_sampled_backend(name: str, fn: Callable) -> None:
    """Register a record-sampled FC path for an existing backend:
    ``fn(state, pkts, sample_idx, **kw) -> (state, feats (m, F))``."""
    _SAMPLED[resolve_backend(name)] = fn


def _scan_sampled(state, pkts, sample_idx, **_kw):
    from repro.core.parallel import process_parallel_sampled
    return process_parallel_sampled(state, pkts, sample_idx)


def _bucketed_sampled(state, pkts, sample_idx, buckets: int = 4, **_kw):
    from repro.core.bucketed import process_bucketed_sampled
    return process_bucketed_sampled(state, pkts, sample_idx, buckets=buckets)


register_sampled_backend("scan", _scan_sampled)
register_sampled_backend("bucketed", _bucketed_sampled)


def compute_features_sampled(state: Dict, pkts: Dict[str, jax.Array],
                             sample_idx: jax.Array, backend: str = "scan",
                             mode: str = "exact", **kw
                             ) -> Tuple[Dict, jax.Array]:
    """One batch through the FC backend, emitting ONLY the sampled rows.

    Returns ``(new_state, feats (m, N_FEATURES))`` with ``new_state``
    identical to :func:`compute_features` and ``feats`` row-for-row equal
    to ``compute_features(...)[1][sample_idx]``.  Backends with a native
    record-sampled path (``scan``, ``bucketed``) skip materialising the
    unsampled rows;
    everything else computes the full matrix and gathers.  Traceable — the
    fused serving step (serving/fused.py) inlines it into one jit.
    """
    name = resolve_backend(backend)
    spec = state_spec_of(state)
    if spec.compute is not None:
        new_state, feats = spec.compute(state, pkts, mode=mode,
                                        fc_backend=name, **kw)
        return new_state, feats[sample_idx]
    fn = _SAMPLED.get(name)
    if fn is not None and mode == "exact":
        return fn(state, pkts, sample_idx, **kw)
    new_state, feats = compute_features(state, pkts, backend=name,
                                        mode=mode, **kw)
    return new_state, feats[sample_idx]


def default_backend(mode: str = "exact") -> str:
    """The sensible default for a given arithmetic mode."""
    return "scan" if mode == "exact" else "serial"
