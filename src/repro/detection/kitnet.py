"""KitNET (Kitsune's detector, arXiv: NDSS'18) in JAX.

Architecture (§3.4 of the Peregrine paper):
  * Feature Mapper — clusters the F features into k groups of size <= m by
    correlation distance (hierarchical clustering, as Kitsune's FM).
  * Ensemble layer — one small autoencoder per group
    (d -> ceil(0.75 d) -> d, sigmoid), inputs 0-1 normalised per feature.
  * Output layer — an autoencoder over the k ensemble RMSEs; the final
    anomaly score is its reconstruction RMSE.

Training is single-pass minibatched SGD in JAX (the original is per-record
SGD; same objective, batched for TPU/vector efficiency — deviation recorded
in DESIGN.md §3).  All ensemble AEs run as ONE padded batched einsum so the
MD stage is a single fused computation; the fused Pallas version of the
ensemble layer plugs in through ``detection.md_backends.score_records``
(``backend="pallas"``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from scipy.cluster.hierarchy import linkage, to_tree


# ---------------------------------------------------------------------------
# Feature mapper
# ---------------------------------------------------------------------------
def feature_map(train_feats: np.ndarray, max_size: int = 10) -> List[np.ndarray]:
    """Cluster feature indices by correlation distance; clusters <= max_size.

    Degenerate inputs are handled rather than crashing scipy: fewer than two
    features yield an empty condensed distance (``linkage`` rejects it), so
    they fall back to a single cluster; constant/empty traces can produce
    NaN correlation distances, which are sanitised to the maximum distance
    before clustering.
    """
    X = np.asarray(train_feats, np.float64)
    F = X.shape[1]
    if F < 2:
        # single (possibly empty) cluster — nothing to hierarchically split
        return [np.arange(F, dtype=np.int32)] if F else []
    std = X.std(0)
    Xn = (X - X.mean(0)) / np.where(std > 1e-9, std, 1.0)
    corr = np.clip((Xn.T @ Xn) / max(X.shape[0], 1), -1.0, 1.0)
    dist = 1.0 - np.abs(corr)
    np.fill_diagonal(dist, 0.0)
    # NaN/inf arise from empty or non-finite traces; treat as "uncorrelated"
    dist = np.clip(np.nan_to_num(dist, nan=1.0, posinf=1.0, neginf=1.0),
                   0.0, 1.0)
    # condensed form
    iu = np.triu_indices(F, 1)
    Z = linkage(dist[iu], method="average")
    root = to_tree(Z)

    clusters: List[np.ndarray] = []

    def walk(node):
        ids = node.pre_order(lambda x: x.id)
        if len(ids) <= max_size or node.is_leaf():
            clusters.append(np.asarray(sorted(ids), np.int32))
        else:
            walk(node.left)
            walk(node.right)

    walk(root)
    return clusters


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class KitNet:
    idx: jnp.ndarray          # (k, m) feature indices per AE (padded)
    mask: jnp.ndarray         # (k, m) 1 for real slots
    params: Dict[str, jnp.ndarray]
    norm_min: jnp.ndarray     # (F,)
    norm_max: jnp.ndarray     # (F,)
    out_min: jnp.ndarray      # (k,) RMSE normalisation for the output AE
    out_max: jnp.ndarray


# a KitNet is a pytree of its arrays, so a fitted net can cross a jit
# boundary as a plain argument (the fused serving step takes it that way)
jax.tree_util.register_pytree_node(
    KitNet,
    lambda net: ((net.idx, net.mask, net.params, net.norm_min, net.norm_max,
                  net.out_min, net.out_max), None),
    lambda _, leaves: KitNet(*leaves))


def _pad_clusters(clusters: List[np.ndarray]) -> Tuple[np.ndarray, np.ndarray]:
    k = len(clusters)
    m = max(len(c) for c in clusters)
    idx = np.zeros((k, m), np.int32)
    mask = np.zeros((k, m), np.float32)
    for i, c in enumerate(clusters):
        idx[i, :len(c)] = c
        mask[i, :len(c)] = 1.0
    return idx, mask


def init_kitnet(key, clusters: List[np.ndarray], n_features: int,
                hidden_ratio: float = 0.75) -> KitNet:
    idx, mask = _pad_clusters(clusters)
    k, m = idx.shape
    h = max(1, int(np.ceil(hidden_ratio * m)))
    kh = max(1, int(np.ceil(hidden_ratio * k)))
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s1, s2 = 1.0 / np.sqrt(m), 1.0 / np.sqrt(k)
    params = {
        "W1": jax.random.normal(k1, (k, m, h)) * s1,
        "b1": jnp.zeros((k, h)),
        "W2": jax.random.normal(k2, (k, h, m)) * s1,
        "b2": jnp.zeros((k, m)),
        "V1": jax.random.normal(k3, (k, kh)) * s2,
        "c1": jnp.zeros((kh,)),
        "V2": jax.random.normal(k4, (kh, k)) * s2,
        "c2": jnp.zeros((k,)),
    }
    return KitNet(idx=jnp.asarray(idx), mask=jnp.asarray(mask), params=params,
                  norm_min=jnp.zeros((n_features,)),
                  norm_max=jnp.ones((n_features,)),
                  out_min=jnp.zeros((k,)), out_max=jnp.ones((k,)))


def _normalize(x, lo, hi):
    # Benign training data lands in [0,1]; eval values beyond the training
    # range are allowed out to 4x so flood-style feature explosions sit far
    # off the AEs' learned manifold (big reconstruction error) without
    # overflowing f32 on constant-in-training columns.  (Kitsune updates its
    # running min/max online instead; deviation recorded in DESIGN.md §3.)
    return jnp.clip((x - lo) / jnp.maximum(hi - lo, 1e-9), 0.0, 4.0)


def ensemble_rmse(params, idx, mask, xb) -> jnp.ndarray:
    """xb: (B, F) normalised features -> per-AE RMSE (B, k)."""
    sub = xb[:, idx]                                  # (B, k, m)
    sub = sub * mask[None]
    h = jax.nn.sigmoid(jnp.einsum("bkm,kmh->bkh", sub, params["W1"])
                       + params["b1"][None])
    y = jax.nn.sigmoid(jnp.einsum("bkh,khm->bkm", h, params["W2"])
                       + params["b2"][None])
    se = ((y - sub) ** 2) * mask[None]
    denom = jnp.maximum(mask.sum(-1), 1.0)
    return jnp.sqrt(se.sum(-1) / denom[None])        # (B, k)


def output_rmse(params, r_norm) -> jnp.ndarray:
    """r_norm: (B, k) normalised ensemble RMSEs -> final score (B,)."""
    h = jax.nn.sigmoid(r_norm @ params["V1"] + params["c1"][None])
    y = jax.nn.sigmoid(h @ params["V2"] + params["c2"][None])
    return jnp.sqrt(jnp.mean((y - r_norm) ** 2, axis=-1))


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------
def train_kitnet(feats_train: np.ndarray, seed: int = 0, max_size: int = 10,
                 lr: float = 0.05, batch: int = 256, epochs: int = 4,
                 md_backend: str = "einsum",
                 md_kw: Optional[Dict] = None) -> KitNet:
    """Fit FM + normalisation on the benign training records, then SGD.

    ``md_backend`` selects the MD implementation for the training-set
    ensemble-RMSE pass (which fixes the output AE's normalisation bounds
    and training inputs) — einsum or the fused Pallas kernel — so the
    fitted net is consistent with the backend used at scoring time;
    ``md_kw`` carries its options (e.g. ``{"bb": 256}`` for pallas).
    SGD itself stays on the einsum graph (it needs gradients).
    """
    F = feats_train.shape[1]
    clusters = feature_map(feats_train, max_size)
    net = init_kitnet(jax.random.PRNGKey(seed), clusters, F)
    lo = jnp.asarray(feats_train.min(0))
    hi = jnp.asarray(feats_train.max(0))
    net = dataclasses.replace(net, norm_min=lo, norm_max=hi)

    X = jnp.asarray(feats_train, jnp.float32)
    n = X.shape[0]
    batch = max(1, min(batch, n))
    nb = max(1, n // batch)
    Xb = X[:nb * batch].reshape(nb, batch, F)

    idx, mask = net.idx, net.mask

    def ens_loss(p, xb):
        xn = _normalize(xb, lo, hi)
        sub = xn[:, idx] * mask[None]
        h = jax.nn.sigmoid(jnp.einsum("bkm,kmh->bkh", sub, p["W1"]) + p["b1"][None])
        y = jax.nn.sigmoid(jnp.einsum("bkh,khm->bkm", h, p["W2"]) + p["b2"][None])
        return jnp.mean(((y - sub) ** 2) * mask[None])

    @jax.jit
    def ens_epoch(p, _):
        def step(p, xb):
            g = jax.grad(ens_loss)(p, xb)
            p = jax.tree_util.tree_map(
                lambda a, b: a - lr * b if a.ndim else a, p, g)
            return p, ()
        p, _ = jax.lax.scan(step, p, Xb)
        return p, ()

    ens_params = {k: v for k, v in net.params.items() if k[0] in "Wb"}
    ens_params, _ = jax.lax.scan(ens_epoch, ens_params, None, length=epochs)
    params = {**net.params, **ens_params}

    # ensemble RMSEs over training set -> output AE normalisation + training
    # (dispatched so pallas-scored deployments also train through the kernel)
    from repro.detection.md_backends import ensemble_rmse_records
    r_train = ensemble_rmse_records(params, idx, mask,
                                    _normalize(X, lo, hi),
                                    backend=md_backend, **(md_kw or {}))
    r_lo, r_hi = r_train.min(0), r_train.max(0)
    rn = _normalize(r_train, r_lo, r_hi)
    k = rn.shape[1]
    Rb = rn[:nb * batch].reshape(nb, batch, k)

    def out_loss(p, rb):
        h = jax.nn.sigmoid(rb @ p["V1"] + p["c1"][None])
        y = jax.nn.sigmoid(h @ p["V2"] + p["c2"][None])
        return jnp.mean((y - rb) ** 2)

    @jax.jit
    def out_epoch(p, _):
        def step(p, rb):
            g = jax.grad(out_loss)(p, rb)
            p = jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)
            return p, ()
        p, _ = jax.lax.scan(step, p, Rb)
        return p, ()

    out_params = {k2: v for k2, v in params.items() if k2[0] in "Vc"}
    out_params, _ = jax.lax.scan(out_epoch, out_params, None, length=epochs)
    params = {**params, **out_params}

    return dataclasses.replace(net, params=params, out_min=r_lo, out_max=r_hi)


@jax.jit
def _score(params, idx, mask, lo, hi, r_lo, r_hi, X):
    xn = _normalize(X, lo, hi)
    r = ensemble_rmse(params, idx, mask, xn)
    rn = _normalize(r, r_lo, r_hi)
    return output_rmse(params, rn)


def score_kitnet(net: KitNet, feats: np.ndarray) -> np.ndarray:
    """Anomaly RMSE score per record (the einsum MD backend; use
    ``detection.md_backends.score_records`` to select backends by name)."""
    X = jnp.asarray(feats, jnp.float32)
    return np.asarray(_score(net.params, net.idx, net.mask, net.norm_min,
                             net.norm_max, net.out_min, net.out_max, X))
