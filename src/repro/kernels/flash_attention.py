"""Flash attention (forward) as a Pallas TPU kernel.

Blockwise-softmax attention with causal masking, sliding window, logit
soft-capping and GQA — the compute hot-spot of every assigned LM arch.

Grid: (batch*q_heads, q_blocks, kv_blocks); the kv axis is innermost and
sequential, carrying the running max / denominator / accumulator in VMEM
scratch across kv steps (the standard TPU flash schedule).  Block shapes are
MXU-aligned (multiples of 128 on the matmul dims when head_dim allows).

Validated in interpret mode against ``ref.flash_attention_ref`` over shape /
dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int, softcap: float,
                 bq: int, bk: int, seq_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = k_pos < seq_kv                                 # kv padding
    if causal:
        ok &= q_pos >= k_pos
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                                 # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "bq", "bk",
                              "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """q: (B, H, Sq, D); k/v: (B, K, Sk, D) with H a multiple of K.

    Returns (B, H, Sq, D) in q.dtype.
    """
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(D)
    bq = min(bq, max(Sq, 8))
    bk = min(bk, max(Sk, 8))
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    Sq_p, Sk_p = nq * bq, nk * bk
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Sq_p - Sq), (0, 0)))
    if Sk_p != Sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sk_p - Sk), (0, 0)))
    qf = q.reshape(B * H, Sq_p, D)
    kf = k.reshape(B * K, Sk_p, D)
    vf = v.reshape(B * K, Sk_p, D)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, seq_kv=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j, G=G: (h // G, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j, G=G: (h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq_p, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq_p, D)[:, :, :Sq]
