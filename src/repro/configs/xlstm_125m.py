"""xlstm-125m — [ssm] 12L d_model=768 4H vocab=50304, sLSTM + mLSTM blocks.
d_ff=0 per assignment: the mLSTM up-projection (x2) and sLSTM gated FFN
(pf=4/3) carry the FFN budget, per the xLSTM paper. sLSTM at blocks {1, 7}
(paper's 7:1-ish mix at small scale). [arXiv:2405.04517; unverified]"""
from repro.configs.base import ArchConfig, SSM

CONFIG = ArchConfig(
    name="xlstm-125m",
    family=SSM,
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    slstm_at=(1, 7),
    ssm_chunk=128,
)
