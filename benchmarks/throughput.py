"""Figure 8 analog: system throughput vs sampling rate.

The paper measures 100G-link packet rates against the ML classifier's
record-processing rate, binary-searching the highest stable rate.  Offline
(CPU-only) we measure the two component rates directly and derive the same
curve:

    stable_pps(rate) = min(FC_pps, MD_records_per_s * rate)

FC_pps is measured per backend through the unified
``repro.core.backends.compute_features`` API in *streaming steady state*:
the trace is cut into fixed-size chunks and fed through the backend with
flow-table state carried across chunk boundaries (exactly what
``DetectionService.process_stream`` does in deployment), timed after a full
warm-up pass.  Any registered backend can be benchmarked by name
(``--backends serial,scan,bucketed:4,pallas,sharded:4`` — ``sharded:S`` /
``bucketed:S`` select the partition / bucket count):

  * serial  — the per-packet oracle (lax.scan), exact arithmetic;
  * scan    — TPU-native segmented-scan pipeline;
  * bucketed — the scan pipeline over S balanced flow-hash buckets
    (core/bucketed.py): per-bucket scans + an O(S) tail-carry combine,
    mesh-placeable via shard_map over the ``flow_shards`` axis;
  * pallas  — the full-feature Pallas kernel (interpret mode on CPU; on TPU
    this is the line-rate path);
  * sharded — hash-partitioned flow tables, S shards vmapped (or placed on
    a mesh); serial per-packet semantics inside each shard.

Interpret-mode pallas rows cost ~60x scan wall time on CPU while measuring
an emulator, not a kernel — ``--skip-interpret`` (DEFAULT when no real
accelerator is present and the backend list is the stock one) drops them.
Pass ``--no-skip-interpret``, or name pallas in an explicit ``--backends``
list, to keep them: on real TPU the flag resolves off and pallas is
measured like everything else.

``--stage full`` additionally measures the WHOLE pipeline — FC -> per-epoch
record sampling -> per-chunk MD scoring — for every (fc_backend x
md_backend) pair through ``DetectionService.process_stream``, along BOTH
deployment paths (DESIGN.md §6/§8):

  * ``pipeline_<fc>_x_<md>_pps`` — the staged path: per-chunk host
    round-trips between FC, numpy epoch sampling, and MD;
  * ``pipeline_fused_<fc>_x_<md>_pps`` — the fused device-resident step
    (``serving/fused.py``): one donated jit per chunk, on-device epoch
    gather, chunk k+1 dispatched before chunk k's sampled scores drain;

plus per-chunk latency percentiles (``*_latency`` → p50/p99 ms) for each.
MD backends (``--md-backends einsum,pallas``) come from
``repro.detection.md_backends`` — the batched einsum path or the fused
Pallas ensemble kernel (DESIGN.md §3).  ``--assert-fused-speedup R`` turns
the run into a perf-smoke check: it fails unless every measured fused pair
is at least R× its staged twin *in the same run* (a ratio, so slow CI
hosts don't flake it).

Reading the staged-vs-fused rows: on a single CPU device both paths share
the same FC compute, which dominates a 2048-packet chunk, so the fused
win here is the few ms/chunk of host round-trips plus the record-sampled
feature emission — expect single-digit-to-tens of percent, converging to
pps parity with FC-alone (``service_stream_pps`` ≈ ``scan_pps``).  The
structural win is the dataflow: per-chunk host cost is O(records), not
O(packets), and on an accelerator (where a host sync stalls the device
and the feature matrix crosses PCIe) the staged path's per-chunk
synchronisation is the multiplier the paper's offloading argument is
about.  Beware contended hosts: staged rows degrade far more than fused
ones under memory/CPU pressure (the staged path allocates the full
(n, 80) matrix host-side every chunk), which can inflate the apparent
ratio — compare rows from the same idle-host run only.

``--mesh`` adds the multi-device scale-out rows (DESIGN.md §12): every
bucketed:S backend, the fused bucketed pipeline, and the multi-tenant
engine measured under a D-device ``flow_shards``/``tenants`` mesh for
D∈{1,2,4} up to the device count (``<label>_mesh<D>_pps`` etc.); pair it
with ``--devices N`` to force N host devices on CPU
(``--xla_force_host_platform_device_count``, applied before jax init).
``--assert-bucketed-speedup R --mesh`` gates the multiplier: each
bucketed:S placed on the full mesh must be ≥ R× its own unplaced
single-device stream, interleaved same-run.  Forced CPU "devices"
timeshare the physical cores, so the achievable multiplier is bounded by
real cores, not D.

The TPU projection for the scan pipeline is derived from its roofline bytes
(see EXPERIMENTS.md §Perf — Peregrine pipeline).

Note on the partitioned backends on this host: ``sharded`` keeps the
serial oracle's per-packet scan *inside* each shard and every shard walks
the full packet batch, so on ONE device it does ~S× the serial work on the
same packet-serial critical path — it lands in ``serial``'s speed class,
far below ``scan``; its win is slot capacity and mesh placement of the
*tables* (switch-partitioned SRAM → TPU VMEM), and it remains the only
partitioned backend for ``switch``-mode arithmetic.  ``bucketed``
supersedes it for exact-mode throughput: it partitions the *packets* (S
balanced buckets of the flow-hash-sorted batch, scanned independently), so
per-bucket work is 1/S of the batch and the buckets are mesh-placeable via
``shard_map``.  On one CPU device the buckets serialise onto the same
cores, so expect ``bucketed:S`` ≈ ``scan`` (within the chunk-dispatch
overhead of the extra carry combine) rather than an S× win — the
multiplier needs multiple devices; ``--assert-bucketed-speedup`` gates the
single-host invariants (bucketed ≥ RATIO × scan, and ≥ 2× its sharded:S
twin when one is in the backend list), re-measuring each pair with the
two streams *interleaved* so host-load drift between separately-timed
rows cannot flake the ratio.  All backends are measured in ``exact`` mode
so rates are directly comparable.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, Optional, Tuple


def _apply_devices_flag(argv=None) -> int:
    """Honour ``--devices N`` BEFORE jax initialises its backend.

    ``--xla_force_host_platform_device_count`` is read once, at backend
    init, so it cannot be an ordinary argparse option consumed after
    ``import jax`` — this peeks at argv at import time and prepends the
    flag to ``XLA_FLAGS``.  CPU-only: on hosts with real accelerators the
    flag is a no-op and the mesh rows bind physical devices instead
    (DESIGN.md §12).  Returns the requested count (0 = not requested).
    """
    argv = sys.argv[1:] if argv is None else argv
    n = 0
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            n = int(argv[i + 1])
        elif a.startswith("--devices="):
            n = int(a.split("=", 1)[1])
    if n > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n} "
            + os.environ.get("XLA_FLAGS", ""))
    return n


_REQUESTED_DEVICES = _apply_devices_flag() if __name__ == "__main__" else 0

import jax
import jax.numpy as jnp

from benchmarks.common import save, timeit
from repro.core import (available_backends, compute_features, init_state,
                        resolve_backend)
from repro.data.pipeline import phv_batches
from repro.detection.kitnet import score_kitnet, train_kitnet
from repro.detection.md_backends import (available_md_backends,
                                         validate_md_options)
from repro.distributed.sharding import flow_mesh
from repro.serving import DetectionEngine, DetectionService
from repro.traffic import synth_trace, to_jnp

import numpy as np

# the serial-semantics backends are orders of magnitude slower per packet:
# measure them on a truncated stream so the benchmark finishes
# (``sketch`` here is the pure-JAX per-packet reference scan — the Pallas
# sketch kernel shares pallas's interpret-mode caveat on CPU)
_BACKEND_PKTS = {"serial": 2000, "sharded": 2000, "scan": None,
                 "bucketed": None, "pallas": 4096, "sketch": 2000}

DEFAULT_BACKENDS = ("serial,scan,bucketed:4,bucketed:16,pallas,"
                    "sharded:4,sharded:16,sketch:2")

# backends taking a ``:S`` partition-count suffix -> the kwarg it sets
_SUFFIX_KW = {"sharded": "shards", "bucketed": "buckets", "sketch": "rows"}


def parse_backend(spec: str) -> Tuple[str, Dict, str]:
    """``"sharded:16"``/``"bucketed:4"``/``"sketch:2"`` ->
    (name, kwargs, result label)."""
    if ":" in spec:
        name, arg = spec.split(":", 1)
        name = resolve_backend(name)
        kw = _SUFFIX_KW.get(name)
        if kw is None:
            raise ValueError(f"only {sorted(_SUFFIX_KW)} take a :S suffix, "
                             f"got {spec!r}")
        return name, {kw: int(arg)}, f"{name}{arg}"
    return resolve_backend(spec), {}, resolve_backend(spec)


def _trunc_chunked(split: Dict, backend_name: str, n_pkts: int,
                   chunk: int) -> Tuple[Dict, int, int]:
    """Shared trace-truncation/chunking setup for every streaming
    measurement: truncate to the backend's measurement cap, then floor to
    whole chunks so the stream is equal-size chunks (single compilation,
    steady state).  Returns (truncated split, n_packets, chunk_size)."""
    cap = _BACKEND_PKTS.get(backend_name)
    n = n_pkts if cap is None else min(cap, n_pkts)
    n = min(n, len(split["ts"]))
    c = min(chunk, n)
    n = (n // c) * c
    return {k: v[:n] for k, v in split.items()}, n, c


def _snap(state):
    """Donation-safe state snapshot: fused steps consume the handle they
    are passed, so benchmark restore points must be real copies."""
    return jax.tree_util.tree_map(jnp.copy, state)


def _warm_stream(spec: str, data: Dict, n_pkts: int, chunk: int,
                 n_slots: int, devices: int = 0):
    """(stream callable over warmed state, n_packets, resolved name,
    label) for one backend spec — the shared measurement unit of
    ``fc_rates``, ``mesh_rates``, and the interleaved
    ``--assert-bucketed-speedup`` gate.  ``devices=D`` (> 0) runs every
    chunk under ``distributed.sharding.flow_mesh(D)``, so partitioned
    backends place their buckets on a D-device ``flow_shards`` mesh;
    equal meshes hash equal, so re-entering the context per call still
    hits the one compiled executable."""
    name, kw, label = parse_backend(spec.strip())
    tr, n, c = _trunc_chunked(data["train"], name, n_pkts, chunk)
    pk = to_jnp(tr)
    chunks = [{k: v[i:i + c] for k, v in pk.items()}
              for i in range(0, n, c)]
    # a "sketch:R" spec is a STATE backend: build the Count-Min state and
    # let compute_features dispatch structurally (the kwargs configure the
    # state, not the FC call)
    if name == "sketch":
        state0, fc_kw = init_state(n_slots, state_backend="sketch", **kw), {}
    else:
        state0, fc_kw = init_state(n_slots), kw

    def run(state):
        f = None
        for ch in chunks:
            state, f = compute_features(state, ch, backend=name,
                                        mode="exact", **fc_kw)
        jax.block_until_ready(f)
        return state

    if devices:
        def stream(state):
            with flow_mesh(devices):
                return run(state)
    else:
        stream = run

    warm = stream(state0)      # compile + steady-state tables
    return (lambda: stream(warm)), n, name, label


def fc_rates(n_pkts: int = 20000, n_slots: int = 8192,
             backends=tuple(DEFAULT_BACKENDS.split(",")),
             chunk: int = 2048) -> Dict[str, float]:
    """Steady-state streaming FC rate per backend: fixed-size chunks with
    flow-table state carried across chunk boundaries."""
    data = synth_trace("mirai", n_train=n_pkts, n_benign_eval=1000,
                       n_attack=1000, seed=0)

    out = {}
    for spec in backends:
        stream, n, name, label = _warm_stream(spec, data, n_pkts, chunk,
                                              n_slots)
        reps = 3 if name in ("scan", "bucketed") else 1
        t = timeit(stream, reps=reps, warmup=0)
        out[f"{label}_pps"] = n / t
    return out


def interleaved_fc_ratio(spec_a: str, spec_b: str, n_pkts: int = 8000,
                         chunk: int = 2048, n_slots: int = 8192,
                         rounds: int = 10, devices_a: int = 0,
                         devices_b: int = 0) -> float:
    """pps(a) / pps(b) from the two backends' streams ALTERNATED round by
    round, taking each backend's BEST round.  ``fc_rates`` measures
    backends minutes apart, so host-load drift between the two
    measurements can swamp a same-run ratio gate; alternating gives both
    backends the same contention profile, and the min-time estimator (the
    classic noise-robust choice) compares their uncontended speeds —
    identical work on this class of 2-core shared host measures with up to
    ~4× wall-time spread, which medians do not survive but best-of-rounds
    does.  ``devices_a``/``devices_b`` place either side on a
    ``flow_mesh(D)`` (the ``--mesh`` gate compares the same backend placed
    vs unplaced)."""
    data = synth_trace("mirai", n_train=n_pkts, n_benign_eval=1000,
                       n_attack=1000, seed=0)
    sa, na, _, _ = _warm_stream(spec_a, data, n_pkts, chunk, n_slots,
                                devices=devices_a)
    sb, nb, _, _ = _warm_stream(spec_b, data, n_pkts, chunk, n_slots,
                                devices=devices_b)
    ta, tb = [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        sa()
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        sb()
        tb.append(time.perf_counter() - t0)
    return (na / min(ta)) / (nb / min(tb))


def service_rate(n_pkts: int = 8000, epoch: int = 256,
                 chunk: int = 2048) -> float:
    """End-to-end ``DetectionService.process_stream`` packet rate (FC +
    record sampling + KitNET scoring) on the default batch backend."""
    data = synth_trace("mirai", n_train=n_pkts, n_benign_eval=n_pkts // 2,
                       n_attack=n_pkts // 2, seed=0)
    svc = DetectionService(epoch=epoch, n_slots=8192, mode="exact")
    svc.observe_stream(data["train"], chunk=chunk)
    svc.fit()
    n_eval = len(data["eval"]["ts"])
    svc.process_stream(data["eval"], chunk=chunk)       # warm-up/compile
    t = timeit(lambda: svc.process_stream(data["eval"], chunk=chunk),
               reps=3, warmup=0)
    return n_eval / t


def _fitted_service(n_pkts: int, epoch: int, chunk: int, n_slots: int,
                    **svc_kw) -> Tuple[DetectionService, Dict, int]:
    """One trained service + its eval split — the shared setup of the
    engine and mesh measurements (``--tenants`` /
    ``--assert-engine-overhead`` / ``--mesh``)."""
    data = synth_trace("mirai", n_train=n_pkts, n_benign_eval=n_pkts // 2,
                       n_attack=n_pkts // 2, seed=0)
    svc = DetectionService(epoch=epoch, n_slots=n_slots, mode="exact",
                           **svc_kw)
    svc.observe_stream(data["train"], chunk=chunk)
    svc.fit()
    ev = {k: v for k, v in data["eval"].items() if k != "label"}
    return svc, ev, len(ev["ts"])


def _engine_run(svc: DetectionService, ev: Dict, n_tenants: int,
                chunk: int) -> DetectionEngine:
    """One full multi-tenant pass: fresh engine (the tenant-step jit is
    module-cached, so only the first call compiles), every tenant fed the
    same eval trace through the backpressured ``run`` driver."""
    eng = DetectionEngine.from_service(svc, n_tenants=n_tenants,
                                       chunk=chunk, queue_depth=4)
    tids = [eng.add_tenant() for _ in range(n_tenants)]
    eng.run({t: ev for t in tids})
    return eng


def engine_rates(n_tenants: int = 4, n_pkts: int = 8000, epoch: int = 256,
                 chunk: int = 2048, n_slots: int = 8192,
                 reps: int = 3) -> Dict[str, float]:
    """Multi-tenant engine throughput: N tenant streams multiplexed
    through the tenant-batched fused step (``serving/engine.py``).  Emits
    aggregate packets/s across all tenants plus the WORST tenant's p99
    per-chunk latency — the two numbers a switch operator sizes against."""
    svc, ev, n_eval = _fitted_service(n_pkts, epoch, chunk, n_slots)
    _engine_run(svc, ev, n_tenants, chunk)          # compile + warm-up
    best_t, worst_p99, collisions = None, 0.0, 0
    for _ in range(reps):
        t0 = time.perf_counter()
        eng = _engine_run(svc, ev, n_tenants, chunk)
        dt = time.perf_counter() - t0
        if best_t is None or dt < best_t:
            best_t = dt
            st = eng.stats()["tenants"]
            worst_p99 = max(v["p99_ms"] for v in st.values())
            # dense-state slot pressure: distinct flows that shared a table
            # slot with another flow, summed over tenants (0 for sketch
            # states, which have no per-flow slots to collide)
            collisions = sum(v.get("slot_collisions", 0)
                             for v in st.values())
    return {f"engine_tenants{n_tenants}_agg_pps": n_tenants * n_eval / best_t,
            f"engine_tenants{n_tenants}_worst_tenant_p99": worst_p99,
            f"engine_tenants{n_tenants}_slot_collisions": collisions}


def interleaved_engine_ratio(n_tenants: int = 4, n_pkts: int = 8000,
                             epoch: int = 256, chunk: int = 2048,
                             n_slots: int = 8192, rounds: int = 5) -> float:
    """engine_aggregate_pps(N tenants) / single_stream_fused_pps, the two
    measured ALTERNATED round by round with best-of-rounds per side (same
    noise-robust estimator as ``interleaved_fc_ratio``).  The engine does
    N traces of work per round, so a ratio near N·(fused pps)/… collapsing
    to ~1.0 means tenant-batching amortises: N streams cost about one."""
    svc, ev, n_eval = _fitted_service(n_pkts, epoch, chunk, n_slots)
    state0, count0 = _snap(svc.state), svc.pkt_count

    def single():
        svc.state = _snap(state0)
        svc.pkt_count = count0
        svc.process_stream(ev, chunk=chunk, fused=True)

    single()                                         # compile + warm-up
    _engine_run(svc, ev, n_tenants, chunk)
    te, ts = [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        _engine_run(svc, ev, n_tenants, chunk)
        te.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        single()
        ts.append(time.perf_counter() - t0)
    return (n_tenants * n_eval / min(te)) / (n_eval / min(ts))


def _mesh_device_counts() -> Tuple[int, ...]:
    """The mesh sizes worth measuring on this host: N∈{1,2,4} clipped to
    the visible device count (forced via ``--devices`` on CPU, physical on
    accelerators)."""
    nd = jax.device_count()
    return tuple(d for d in (1, 2, 4) if d <= nd)


def mesh_rates(backends, n_pkts: int = 8000, chunk: int = 2048,
               n_slots: int = 8192, n_tenants: int = 4,
               epoch: int = 256) -> Dict[str, float]:
    """Multi-device scale-out rows (``--mesh``): every bucketed:S spec's
    FC stream, the fused bucketed pipeline, and the multi-tenant engine,
    each measured under ``flow_mesh(D)`` for D∈{1,2,4}∩devices —
    ``<label>_mesh<D>_pps``, ``pipeline_fused_<label>_mesh<D>_pps``, and
    ``engine_tenants<T>_mesh<D>_agg_pps``.  The D=1 row is the same-run
    single-device baseline the multiplier is read against; ``common.save``
    refuses any ``_mesh<D>_`` row whose D exceeds the stamped
    ``device_count``, so committed payloads cannot mix topologies.

    Regime note (DESIGN.md §12): under the FORCED harness all D "devices"
    timeshare the host's physical cores, so the measurable multiplier is
    bounded by real cores, not by D — on a single-core host expect ≈ 1×;
    the forced harness proves the collective structure scales, real
    accelerators provide the hardware."""
    data = synth_trace("mirai", n_train=n_pkts, n_benign_eval=1000,
                       n_attack=1000, seed=0)
    b_specs = [b for b in backends if parse_backend(b)[0] == "bucketed"]
    out = {}
    for spec in b_specs:
        for d in _mesh_device_counts():
            if parse_backend(spec)[1].get("buckets", 1) % d:
                continue        # buckets must divide over the mesh axis
            stream, n, _, label = _warm_stream(spec, data, n_pkts, chunk,
                                               n_slots, devices=d)
            t = timeit(stream, reps=3, warmup=0)
            out[f"{label}_mesh{d}_pps"] = n / t
    if b_specs:
        # fused pipeline (FC → epoch gather → KitNET in one jit) on the
        # first bucketed spec: the mesh placement resolves at trace time
        # inside the fused step, so this measures the whole serving path
        name, kw, label = parse_backend(b_specs[0])
        svc, ev, n_eval = _fitted_service(n_pkts, epoch, chunk, n_slots,
                                          backend=name, **kw)
        state0, count0 = _snap(svc.state), svc.pkt_count
        for d in _mesh_device_counts():
            if kw.get("buckets", 1) % d:
                continue

            def run():
                svc.state = _snap(state0)
                svc.pkt_count = count0
                with flow_mesh(d):
                    svc.process_stream(ev, chunk=chunk, fused=True)

            run()                               # compile + warm-up
            t = timeit(run, reps=3, warmup=0)
            out[f"pipeline_fused_{label}_mesh{d}_pps"] = n_eval / t
    # multi-tenant engine: the tenant axis spreads over the same mesh
    # (serving/fused.make_tenant_step's ``tenants`` rule placement)
    svc, ev, n_eval = _fitted_service(n_pkts, epoch, chunk, n_slots)
    for d in _mesh_device_counts():

        def erun():
            with flow_mesh(d):
                _engine_run(svc, ev, n_tenants, chunk)

        erun()                                  # compile + warm-up
        t = timeit(erun, reps=3, warmup=0)
        out[f"engine_tenants{n_tenants}_mesh{d}_agg_pps"] = (
            n_tenants * n_eval / t)
    return out


def md_rate(n_train: int = 4000, n_score: int = 8192):
    rng = np.random.default_rng(0)
    feats = rng.random((n_train, 80)).astype(np.float32)
    net = train_kitnet(feats, seed=0)
    batch = rng.random((n_score, 80)).astype(np.float32)
    t = timeit(lambda: score_kitnet(net, batch), reps=3)
    return n_score / t


def _latency_pcts(lats_s) -> Dict[str, float]:
    a = np.asarray(lats_s) * 1e3
    return {"p50_ms": float(np.percentile(a, 50)),
            "p99_ms": float(np.percentile(a, 99))}


def pipeline_rates(backends, md_backends=("einsum", "pallas"),
                   n_pkts: int = 8000, epoch: int = 64, n_slots: int = 8192,
                   chunk: int = 2048) -> Dict[str, object]:
    """``--stage full``: steady-state pps of the WHOLE pipeline — FC ->
    per-epoch record sampling -> per-chunk MD scoring — for every
    (fc_backend x md_backend) pair, measured through
    ``DetectionService.process_stream`` exactly as deployed (state + packet
    count carried across chunks, scores emitted per chunk), along both the
    staged (``fused=False``) and the fused device-resident
    (``fused=True``, ``serving/fused.py``) paths, plus per-chunk latency
    percentiles for each.  ``epoch=64`` keeps the MD stage on ~1/64 of the
    packets so its cost is visible in the pair rates rather than rounding
    away."""
    data = synth_trace("mirai", n_train=n_pkts, n_benign_eval=n_pkts // 2,
                       n_attack=n_pkts // 2, seed=0)
    out = {}
    for spec in backends:
        name, kw, label = parse_backend(spec.strip())
        tr, ntr, c = _trunc_chunked(data["train"], name, n_pkts, chunk)
        ev, nev, c_ev = _trunc_chunked(data["eval"], name, ntr, c)
        # the FC training pass is identical for every MD backend: observe
        # once, snapshot, then fit + measure per MD backend from the
        # snapshot (fit() consumes the collected records and sets the
        # threshold, so both are restored per pair)
        svc = DetectionService(epoch=epoch, n_slots=n_slots, mode="exact",
                               backend=name, **kw)
        svc.observe_stream(tr, chunk=c)
        feats0 = list(svc._train_feats)
        state0 = _snap(svc.state)
        count0 = svc.pkt_count

        def reset():
            svc.state = _snap(state0)
            svc.pkt_count = count0

        for md in md_backends:
            # re-validate against the service's md_kw on every switch, the
            # same invariant the DetectionService constructor establishes
            svc.md_backend = validate_md_options(md.strip(), svc.md_kw)
            svc._train_feats = list(feats0)
            svc.threshold = None
            svc.fit()
            reps = 3 if name in ("scan", "bucketed", "pallas") else 1
            for fused in (False, True):
                tag = (f"pipeline{'_fused' if fused else ''}"
                       f"_{label}_x_{svc.md_backend}")
                reset()
                svc.process_stream(ev, chunk=c_ev, fused=fused)  # warm-up
                reset()
                t = timeit(
                    lambda: svc.process_stream(ev, chunk=c_ev, fused=fused),
                    reps=reps, warmup=0)
                out[f"{tag}_pps"] = nev / t
                # per-chunk latency: drain each chunk before the next is
                # dispatched (the sync cost the pipelined stream hides)
                reset()
                lats = []
                for _ in range(reps):
                    for ch in phv_batches(ev, c_ev):
                        t0 = time.perf_counter()
                        svc.process(ch, fused=fused)
                        lats.append(time.perf_counter() - t0)
                out[f"{tag}_latency"] = _latency_pcts(lats)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    # default=None sentinel: an explicitly typed list — even one equal to
    # the stock string — counts as "the user named these backends", which
    # the skip-interpret default respects
    ap.add_argument("--backends", default=None,
                    help=f"comma list from {available_backends()}; "
                         "sharded/bucketed take a :S count suffix "
                         f"(default: {DEFAULT_BACKENDS})")
    ap.add_argument("--md-backends", default="einsum,pallas",
                    help=f"comma list from {available_md_backends()} "
                         "(used by --stage full)")
    ap.add_argument("--stage", choices=("fc", "full"), default="fc",
                    help="fc: per-backend FC component rates (default); "
                         "full: additionally measure the whole "
                         "FC -> record sampling -> MD pipeline per "
                         "(fc_backend x md_backend) pair")
    ap.add_argument("--chunk", type=int, default=2048,
                    help="streaming chunk size (packets per batch)")
    ap.add_argument("--service", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="also measure end-to-end DetectionService pps "
                         "(default: only with the full backend list)")
    ap.add_argument("--assert-fused-speedup", type=float, default=None,
                    metavar="RATIO",
                    help="perf-smoke mode (needs --stage full): exit "
                         "nonzero unless every measured fused pipeline is "
                         "at least RATIO x its staged twin in this run")
    ap.add_argument("--tenants", type=int, default=None, metavar="N",
                    help="also measure the multi-tenant DetectionEngine: "
                         "emits engine_tenants<N>_agg_pps and "
                         "engine_tenants<N>_worst_tenant_p99")
    ap.add_argument("--assert-engine-overhead", type=float, default=None,
                    metavar="RATIO",
                    help="perf-smoke mode: exit nonzero unless the "
                         "N-tenant engine's aggregate pps (N from "
                         "--tenants, default 4) is at least RATIO x the "
                         "single-stream fused pps, the two interleaved "
                         "in the same run")
    ap.add_argument("--assert-bucketed-speedup", type=float, default=None,
                    metavar="RATIO",
                    help="perf-smoke mode: exit nonzero unless every "
                         "measured bucketed:S FC rate is at least RATIO x "
                         "scan in this run AND at least 2x its sharded:S "
                         "twin when one was measured alongside; with "
                         "--mesh the gate instead compares each bucketed:S "
                         "placed on the full device mesh against its own "
                         "unplaced single-device run, interleaved")
    ap.add_argument("--devices", type=int, default=0, metavar="N",
                    help="force N host devices "
                         "(--xla_force_host_platform_device_count, applied "
                         "before jax init by the import-time argv peek; "
                         "no-op on real accelerators)")
    ap.add_argument("--mesh", action="store_true",
                    help="measure multi-device mesh rows "
                         "(<label>_mesh<D>_pps / fused pipeline / engine "
                         "aggregate for D in {1,2,4} up to the device "
                         "count), and switch --assert-bucketed-speedup to "
                         "the placed-vs-unplaced mesh gate")
    ap.add_argument("--skip-interpret", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="drop interpret-mode pallas rows (default: on "
                         "when no real accelerator is present and the "
                         "backend list is the stock one — emulator rows "
                         "dominate CPU wall time; --no-skip-interpret or "
                         "an explicit --backends list keeps them)")
    args = ap.parse_args()
    if args.devices > 1 and jax.device_count() < args.devices:
        raise SystemExit(
            f"--devices {args.devices} requested but jax sees "
            f"{jax.device_count()} (the forced-device flag must precede "
            "backend init — run this file as a script, not -m with a "
            "pre-imported jax)")
    n = 8000 if args.quick else 40000
    stock_list = args.backends is None
    backend_str = DEFAULT_BACKENDS if stock_list else args.backends
    backends = tuple(b.strip() for b in backend_str.split(",") if b.strip())
    skip_interp = args.skip_interpret
    if skip_interp is None:
        skip_interp = jax.default_backend() == "cpu" and stock_list
    if skip_interp:
        kept = tuple(b for b in backends
                     if parse_backend(b)[0] != "pallas")
        if kept != backends:
            print("skip-interpret: dropping interpret-mode pallas rows "
                  "(--no-skip-interpret keeps them)")
        backends = kept
    fc = fc_rates(n_pkts=n, backends=backends, chunk=args.chunk)
    md = md_rate()
    with_service = (args.service if args.service is not None
                    else stock_list)
    svc = (service_rate(n_pkts=min(n, 8000), chunk=args.chunk)
           if with_service else None)
    rates = (1, 64, 1024, 32768)
    # Fig8 pins the curve to the deployable batch pipeline (scan); other
    # backends are component diagnostics, not FC deployment rates
    curve_fc = fc.get("scan_pps", max(fc.values()))
    curve = {r: min(curve_fc, md * r) for r in rates}
    sharded = {k: v for k, v in fc.items() if k.startswith("sharded")}
    bucketed = {k: v for k, v in fc.items() if k.startswith("bucketed")}
    note = ("on-CPU single-core; Fig8 shape: throughput rises with "
            "sampling rate until FC-bound")
    if sharded and "scan_pps" in fc:
        best = max(sharded.values())
        if best <= fc["scan_pps"]:
            note += ("; sharded<=scan on this host: one device pays ~S x "
                     "serial work on the packet-serial oracle path — "
                     "sharded's win is slot capacity / switch-mode "
                     "support; use bucketed:S for exact-mode partitioned "
                     "throughput (see module docstring)")
    if bucketed and "scan_pps" in fc:
        note += ("; bucketed:S ~ scan on a single device (buckets "
                 "serialise onto the same cores; the multiplier needs a "
                 "mesh — see module docstring)")
    out = {**fc, "md_records_per_s": md,
           "stable_pps_at_rate": curve,
           "note": note}
    if svc is not None:
        out["service_stream_pps"] = svc
    n_tenants = args.tenants
    if n_tenants is None and args.assert_engine_overhead is not None:
        n_tenants = 4
    if n_tenants is not None:
        out.update(engine_rates(n_tenants=n_tenants, n_pkts=min(n, 8000),
                                chunk=args.chunk))
    if args.mesh:
        out.update(mesh_rates(backends, n_pkts=min(n, 8000),
                              chunk=args.chunk))
        out["note"] += ("; mesh<D> rows place over D forced host devices "
                        "— the measurable multiplier is bounded by real "
                        "cores, not D, so D>1 rows DROP on few-core hosts "
                        "(DESIGN.md §12)")
    if args.stage == "full":
        mds = tuple(m.strip() for m in args.md_backends.split(",")
                    if m.strip())
        out.update(pipeline_rates(backends, md_backends=mds,
                                  n_pkts=min(n, 8000), chunk=args.chunk))
    for k, v in out.items():
        if isinstance(v, (int, float)):
            print(f"{k:40s} {v:12.0f}")
        elif isinstance(v, dict) and k.endswith("_latency"):
            print(f"{k:40s} p50 {v['p50_ms']:8.2f} ms   "
                  f"p99 {v['p99_ms']:8.2f} ms")
    print("stable pps:", {r: int(v) for r, v in curve.items()})
    save("throughput", out)
    if args.assert_fused_speedup is not None:
        ratio = args.assert_fused_speedup
        bad = []
        pairs = 0
        for k, v in out.items():
            if k.startswith("pipeline_fused_") and k.endswith("_pps"):
                staged = out.get(k.replace("pipeline_fused_", "pipeline_"))
                if staged is None:
                    continue
                pairs += 1
                if v < ratio * staged:
                    bad.append(f"{k}={v:.0f} < {ratio}x staged {staged:.0f}")
        if not pairs:
            raise SystemExit("--assert-fused-speedup needs --stage full "
                             "(no fused pipeline rows were measured)")
        if bad:
            raise SystemExit("fused pipeline slower than staged: "
                             + "; ".join(bad))
        print(f"fused >= {ratio}x staged on all {pairs} measured pairs")
    if args.assert_engine_overhead is not None:
        ratio = args.assert_engine_overhead
        r = interleaved_engine_ratio(n_tenants=n_tenants,
                                     n_pkts=min(n, 8000), chunk=args.chunk)
        print(f"gate: engine x{n_tenants} agg / single fused interleaved "
              f"ratio {r:.2f}")
        if r < ratio:
            raise SystemExit(f"engine aggregate pps = {r:.2f}x single "
                             f"fused stream < {ratio}x")
        print(f"engine x{n_tenants} aggregate >= {ratio}x single-stream "
              "fused pps")
    if args.assert_bucketed_speedup is not None and args.mesh:
        # mesh variant: each bucketed:S placed on the FULL device mesh vs
        # its own unplaced single-device stream, interleaved — the
        # multi-device multiplier the paper's scaling claim rests on.
        # Under the forced-device harness the D "devices" timeshare the
        # host's physical cores, so pass the CI ratio accordingly (a
        # 4-vCPU runner can clear > 1; a 1-core host cannot exceed ~1).
        ratio = args.assert_bucketed_speedup
        nd = jax.device_count()
        if nd < 2:
            raise SystemExit("--assert-bucketed-speedup --mesh needs > 1 "
                             "device (use --devices N on CPU)")
        b_specs = [b for b in backends
                   if parse_backend(b)[0] == "bucketed"
                   and parse_backend(b)[1].get("buckets", 1) % nd == 0]
        if not b_specs:
            raise SystemExit("--assert-bucketed-speedup --mesh needs a "
                             "bucketed:S with S divisible by the device "
                             "count in --backends")
        bad = []
        for spec in b_specs:
            r = interleaved_fc_ratio(spec, spec, n_pkts=min(n, 8000),
                                     chunk=args.chunk, devices_a=nd)
            print(f"gate: {spec} mesh{nd} / single-device interleaved "
                  f"ratio {r:.2f}")
            if r < ratio:
                bad.append(f"{spec} mesh{nd} = {r:.2f}x unplaced < {ratio}x")
        if bad:
            raise SystemExit("mesh multiplier too low: " + "; ".join(bad))
        print(f"mesh{nd} bucketed >= {ratio}x single-device on all "
              f"{len(b_specs)} gated bucket counts")
    elif args.assert_bucketed_speedup is not None:
        ratio = args.assert_bucketed_speedup
        b_specs = [b for b in backends
                   if parse_backend(b)[0] == "bucketed"]
        if not b_specs:
            raise SystemExit("--assert-bucketed-speedup needs at least one "
                             "bucketed:S entry in --backends")
        if not any(parse_backend(b)[0] == "scan" for b in backends):
            raise SystemExit("--assert-bucketed-speedup needs scan in "
                             "--backends (the gate is a same-run ratio)")
        # the gate re-measures each pair INTERLEAVED (round-robin), so
        # host-load drift between two minutes-apart fc_rates rows cannot
        # flake a ratio that is stable under equal contention
        shard_specs = {parse_backend(b)[1].get("shards"): b
                       for b in backends
                       if parse_backend(b)[0] == "sharded"}
        bad = []
        for spec in b_specs:
            s = parse_backend(spec)[1].get("buckets")
            r = interleaved_fc_ratio(spec, "scan", n_pkts=min(n, 8000),
                                     chunk=args.chunk)
            print(f"gate: {spec} / scan interleaved ratio {r:.2f}")
            if r < ratio:
                bad.append(f"{spec} = {r:.2f}x scan < {ratio}x")
            twin = shard_specs.get(s)
            if twin is not None:
                rt = interleaved_fc_ratio(spec, twin, n_pkts=2000,
                                          chunk=args.chunk)
                print(f"gate: {spec} / {twin} interleaved ratio {rt:.2f}")
                if rt < 2.0:
                    bad.append(f"{spec} = {rt:.2f}x {twin} < 2x")
        if bad:
            raise SystemExit("bucketed backend too slow: " + "; ".join(bad))
        print(f"bucketed >= {ratio}x scan (and >= 2x sharded twins) on all "
              f"{len(b_specs)} gated bucket counts")


if __name__ == "__main__":
    main()
