"""End-to-end behaviour of the paper's system (the headline claims, small).

The full-size reproduction lives in benchmarks/ (detection_auc.py etc.);
these tests assert the *directional* claims cheaply so CI guards them.
"""
import numpy as np
import pytest

from repro.detection.metrics import auc
from repro.detection.sweep import sweep_attack
from repro.traffic import synth_trace


@pytest.fixture(scope="module")
def syn_dos_results():
    data = synth_trace("syn_dos", n_train=6000, n_benign_eval=6000,
                       n_attack=6000, seed=0)
    return sweep_attack(data, rates=[1, 256], mode="switch")


def test_peregrine_effective_without_sampling(syn_dos_results):
    assert syn_dos_results["peregrine"][1]["auc"] > 0.8


def test_peregrine_robust_under_sampling(syn_dos_results):
    """The paper's key claim: record sampling preserves detection."""
    r = syn_dos_results["peregrine"]
    assert r[256]["auc"] > 0.8
    assert r[256]["auc"] > r[1]["auc"] - 0.15


def test_kitsune_under_sampling_never_beats_peregrine(syn_dos_results):
    """Fig. 1/7 direction: under sampling the packet-sampled baseline is at
    best equal, and Peregrine stays effective."""
    k = syn_dos_results["kitsune"]
    p = syn_dos_results["peregrine"]
    assert p[256]["auc"] >= k[256]["auc"] - 0.01, (p[256], k[256])
    assert p[256]["auc"] > 0.9


def test_switch_arithmetic_preserves_detection():
    """§5.4: approximate switch arithmetic does not break detection."""
    data = synth_trace("syn_dos", n_train=5000, n_benign_eval=5000,
                       n_attack=5000, seed=1)
    exact = sweep_attack(data, rates=[64], mode="exact")
    sw = sweep_attack(data, rates=[64], mode="switch")
    assert sw["peregrine"][64]["auc"] > 0.8
    assert abs(sw["peregrine"][64]["auc"] - exact["peregrine"][64]["auc"]) < 0.15


def test_f1_reported_at_both_fprs(syn_dos_results):
    r = syn_dos_results["peregrine"][1]
    assert 0.0 <= r["f1_fpr10"] <= 1.0
    assert 0.0 <= r["f1_fpr01"] <= 1.0
