"""TPU-native Peregrine feature computation: segmented associative scans.

The switch updates flow state one packet at a time.  On TPU we exploit that
the decayed-atom update  A_i = delta_i * A_{i-1} + x_i  is a *linear
first-order recurrence*, hence associative:

    (s2, a2) o (s1, a1) = (s1*s2, a1*s2 + a2)

so a whole packet batch is processed in O(log n) depth with
``jax.lax.associative_scan``, *segmented by flow* (sort by stream id, stable,
which preserves time order inside each stream).  Cross-direction state
(stale opposite-direction statistics, last-residual for SR) uses a segmented
"latest-value" scan, which is also associative.

Semantics are bit-for-bit the serial oracle's ``exact`` mode (tested to
float tolerance); the round-robin ``switch`` mode is inherently per-packet
serial and stays on the oracle path.

A batch pays ONE stable argsort per key type (vmapped over the stacked
tables: one sort primitive for the two uni keys, one for the two bi keys).
Everything else is derived: the bidirectional (slot, dir, time) stream
order comes from the (slot, time) channel sort via segmented cumsum ranks
(``_dir_interleave_perm``), and the ``res_last`` store-back reuses that
same permutation instead of re-sorting by the composite key —
``tests/test_fused.py`` pins the sort count at ≤ 4.

Scan primitives are *fused across atoms*: one stacked ``associative_scan``
over ``(n, N_DECAY, 3)`` carries the three decayed atoms (w, LS, SS) of a
stream table, and one stacked latest-value scan over ``(n, 2, N_DECAY, 4)``
carries both directions' stale atoms AND last-residuals of a channel pass —
4 ``associative_scan`` invocations per batch instead of the 11 the unfused
code paid (``tests/test_bucketed.py`` pins the counts).

Both segmented scans also run in *chunked two-level* form (``chunks=S``):
the flow-hash-sorted batch is cut into S equal slices, each slice scanned
independently (depth O(log n/S), mesh-placeable — ``core/bucketed.py``),
and an O(S) exclusive combine over per-chunk tails carries segments that
straddle a cut.  Chunked results equal the flat scan up to fp
reassociation (a few ulp; bit-identical at S=1).

``process_parallel_sampled`` is the record-sampled variant for the fused
serving step (DESIGN.md §8): flow-state updates cover every packet, but
feature statistics are only materialised at the sampled rows.

Requires ``pkts["ts"]`` sorted ascending (streams are time-ordered).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import arith
from repro.core.state import (
    LAMBDAS, N_BI, N_DECAY, N_UNI, packet_slots,
)

_LAM = jnp.asarray(LAMBDAS, jnp.float32)


# ---------------------------------------------------------------------------
# segmented-scan primitives
# ---------------------------------------------------------------------------
def _expand(a, ndim):
    """Append trailing singleton dims until ``a.ndim == ndim``."""
    while a.ndim < ndim:
        a = a[..., None]
    return a


def _linear_combine(l, r):
    fl, sl, al = l
    fr, sr, ar = r
    return (fl | fr,
            jnp.where(fr, sr, sl * sr),
            jnp.where(fr, ar, al * sr + ar))


def _last_combine(l, r):
    fl, vl, xl = l
    fr, vr, xr = r
    found = jnp.where(fr, vr, vl | vr)
    # a fresh segment with no valid element must contribute an explicit
    # zero: ``xr * 0`` would propagate NaN/inf from invalid rows
    val = jnp.where(fr, jnp.where(vr, xr, jnp.zeros_like(xr)),
                    jnp.where(vr, xr, xl))
    return (fl | fr, found, val)


def _chunk2(a, chunks):
    """(n, ...) -> (chunks, n//chunks, ...) — a free row-major reshape."""
    return a.reshape((chunks, a.shape[0] // chunks) + a.shape[1:])


def _excl_shift(t, identity):
    """Inclusive chunk-tail scan -> exclusive carry (identity at chunk 0)."""
    return jnp.concatenate([jnp.full_like(t[:1], identity), t[:-1]], axis=0)


def seg_linear_scan(seg_start, delta, x, chunks: int = 1, shard=None):
    """Segmented A_i = delta_i * A_{i-1} + x_i (A resets at segment starts).

    seg_start: (n,) bool; delta, x: (n, ...) broadcastable (``delta`` may be
    narrower than ``x`` in trailing dims — it broadcasts inside the
    combine).  Returns A with ``x``'s shape.

    ``chunks=S`` runs the two-level form: S independent local scans over
    equal slices of the array (each slice's flows are disjoint except for
    segments straddling a cut), then one exclusive combine over the S
    per-chunk tail summaries, then an O(n) elementwise fix-up — the same
    associative combine, reassociated.  ``shard`` (a
    ``distributed.sharding.ShardContext`` — core/bucketed.py builds one
    from the ambient mesh) places the whole two-level scan under
    ``shard_map`` over the chunk axis with every O(n) step shard-local:
    each device scans its own chunks, all-gathers the O(S) per-chunk tail
    summaries (the ONLY collective — a few KB), runs the tiny combine
    redundantly, and fixes up its own chunks.  No full-batch collectives.
    """
    f = _expand(seg_start, delta.ndim)
    if chunks <= 1:
        _, _, a = jax.lax.associative_scan(
            _linear_combine, (f, delta, x), axis=0)
        return a
    fc, dc, xc = (_chunk2(a, chunks) for a in (f, delta, x))

    if shard is None:
        lf, ls, la = jax.lax.associative_scan(_linear_combine, (fc, dc, xc),
                                              axis=1)
        # carry across cuts: segmented combine over per-chunk tails, excl.
        _, _, pa = jax.lax.associative_scan(
            _linear_combine, (lf[:, -1], ls[:, -1], la[:, -1]), axis=0)
        pa = _excl_shift(pa, 0)
        # combine(carry, local) per element; lf kills the carry as soon as
        # the chunk has seen a real segment start
        a = jnp.where(lf, la, pa[:, None] * ls + la)
        return a.reshape((x.shape[0],) + a.shape[2:])

    n_local = chunks // shard.size

    def local(fc, dc, xc):
        lf, ls, la = jax.lax.associative_scan(_linear_combine, (fc, dc, xc),
                                              axis=1)
        gf, gs, ga = (shard.gather_tails(t)
                      for t in (lf[:, -1], ls[:, -1], la[:, -1]))
        _, _, pa = jax.lax.associative_scan(
            _linear_combine, (gf, gs, ga), axis=0)
        pa = shard.local_chunks(_excl_shift(pa, 0), n_local)
        return jnp.where(lf, la, pa[:, None] * ls + la)

    a = shard.wrap(local)(fc, dc, xc)
    return a.reshape((x.shape[0],) + a.shape[2:])


def seg_last_scan(seg_start, valid, value, chunks: int = 1, shard=None):
    """Segmented latest-valid-value (inclusive). Returns (found, last_value).

    ``found[i]`` False means no valid element yet in i's segment.  ``valid``
    may carry extra trailing dims narrower than ``value`` (e.g. a per-
    direction mask ``(n, 2)`` against values ``(n, 2, ND, k)``) — it
    broadcasts inside the combine, and ``found`` is returned at the
    broadcast shape of ``valid``.  ``chunks``/``shard`` as in
    :func:`seg_linear_scan`.
    """
    f = _expand(seg_start, value.ndim)
    v = _expand(valid, value.ndim)
    if chunks <= 1:
        _, found, val = jax.lax.associative_scan(
            _last_combine, (f, v, value), axis=0)
        return found, val
    fc, vc, xc = (_chunk2(a, chunks) for a in (f, v, value))
    n = value.shape[0]

    if shard is None:
        lf, lv, lx = jax.lax.associative_scan(_last_combine, (fc, vc, xc),
                                              axis=1)
        _, pv, px = jax.lax.associative_scan(
            _last_combine, (lf[:, -1], lv[:, -1], lx[:, -1]), axis=0)
        pv = _excl_shift(pv, False)
        px = _excl_shift(px, 0)
        found = jnp.where(lf, lv, pv[:, None] | lv)
        val = jnp.where(lv, lx,
                        jnp.where(lf, jnp.zeros_like(lx), px[:, None]))
        return (found.reshape((n,) + found.shape[2:]),
                val.reshape((n,) + val.shape[2:]))

    n_local = chunks // shard.size

    def local(fc, vc, xc):
        lf, lv, lx = jax.lax.associative_scan(_last_combine, (fc, vc, xc),
                                              axis=1)
        gf, gv, gx = (shard.gather_tails(t)
                      for t in (lf[:, -1], lv[:, -1], lx[:, -1]))
        _, pv, px = jax.lax.associative_scan(_last_combine, (gf, gv, gx),
                                             axis=0)
        pv = shard.local_chunks(_excl_shift(pv, False), n_local)
        px = shard.local_chunks(_excl_shift(px, 0), n_local)
        found = jnp.where(lf, lv, pv[:, None] | lv)
        val = jnp.where(lv, lx,
                        jnp.where(lf, jnp.zeros_like(lx), px[:, None]))
        return found, val

    found, val = shard.wrap(local)(fc, vc, xc)
    return (found.reshape((n,) + found.shape[2:]),
            val.reshape((n,) + val.shape[2:]))


def _segments(sorted_ids):
    n = sorted_ids.shape[0]
    start = jnp.concatenate([jnp.ones((1,), bool),
                             sorted_ids[1:] != sorted_ids[:-1]])
    end = jnp.concatenate([sorted_ids[1:] != sorted_ids[:-1],
                           jnp.ones((1,), bool)])
    return start, end


def _dir_interleave_perm(start, end, d):
    """Derive the (slot, dir, time) permutation from the (slot, time) sort.

    Given segment markers of the channel-sorted order and the per-element
    direction bits ``d``, returns ``gather`` such that ``X[gather]`` is the
    stable sort by the composite key ``slot*2 + dir`` — computed with
    segmented cumsum ranks in O(n), so the batch pays ONE argsort per key
    type instead of re-sorting for the directional view.
    """
    n = d.shape[0]
    ar = jnp.arange(n)
    seg_first = jax.lax.cummax(jnp.where(start, ar, -1))
    seg_last = jnp.flip(jax.lax.cummin(jnp.flip(jnp.where(end, ar, n))))
    d0 = (d == 0).astype(ar.dtype)
    pref0 = jnp.cumsum(d0)                  # inclusive dir-0 count
    excl0 = pref0 - d0
    base0 = excl0[seg_first]
    n0_seg = pref0[seg_last] - base0        # dir-0 population of the segment
    rank0 = excl0 - base0
    d1 = 1 - d0
    excl1 = jnp.cumsum(d1) - d1
    rank1 = excl1 - excl1[seg_first]
    pos = seg_first + jnp.where(d == 0, rank0, n0_seg + rank1)
    return arith.invert_perm(pos)


# ---------------------------------------------------------------------------
# one directional stream table pass
# ---------------------------------------------------------------------------
def stream_pass(tab, stream_ids, ts, lens, n_streams, order=None,
                sample=None, chunks: int = 1, shard=None):
    """Vectorised decayed-atom update for one table of streams.

    tab: {"last_t","w","ls","ss"} each (n_streams, N_DECAY).
    stream_ids/ts/lens: (n,). Returns (per-packet atoms dict in ORIGINAL
    order, updated table).  ``order`` is the stable sort by stream id; pass
    it when already available (derived or shared) to avoid a re-sort.
    ``sample`` restricts the returned atoms to those original-order rows
    (the table update always covers every packet) — the fused serving step
    only ever reads the sampled records, so the full-width gather back to
    packet order is skipped.  ``chunks``/``shard`` select the two-level
    bucketed scan (core/bucketed.py).

    The three decayed atoms ride ONE stacked scan over ``(n, N_DECAY, 3)``
    (lanes w/ls/ss) — identical per-lane math to three separate scans, a
    third of the scan dispatches.
    """
    n = stream_ids.shape[0]
    if order is None:
        order = jnp.argsort(stream_ids, stable=True)
    inv = arith.invert_perm(order)
    sid = stream_ids[order]
    t = ts[order]
    x = lens[order]
    start, end = _segments(sid)

    # per-packet decay: dt to previous packet in stream (table last_t at start)
    t_prev_in = jnp.concatenate([t[:1], t[:-1]])
    last_t_tab = tab["last_t"][sid]                       # (n, N_DECAY)
    fresh = last_t_tab < 0.0
    dt = jnp.where(start[:, None],
                   jnp.where(fresh, 0.0, t[:, None] - last_t_tab),
                   (t - t_prev_in)[:, None])
    dt = jnp.maximum(dt, 0.0)
    delta = jnp.exp2(-_LAM[None, :] * dt)
    delta = jnp.where(start[:, None] & fresh, 0.0, delta)

    # stacked per-packet increments, table carry folded into first elements:
    # A_1 = delta_1*A_tab + x_1
    xs = jnp.stack([jnp.ones((n, N_DECAY)),
                    jnp.broadcast_to(x[:, None], (n, N_DECAY)),
                    jnp.broadcast_to((x ** 2)[:, None], (n, N_DECAY))],
                   axis=-1)                               # (n, ND, 3)
    tab_a = jnp.stack([tab["w"], tab["ls"], tab["ss"]], axis=-1)[sid]
    x0 = jnp.where(start[:, None, None], xs + delta[..., None] * tab_a, xs)
    atoms3 = seg_linear_scan(start, delta[..., None], x0,
                             chunks=chunks, shard=shard)    # (n, ND, 3)
    w, ls, ss = atoms3[..., 0], atoms3[..., 1], atoms3[..., 2]

    # store back last element of each segment (indices unique by construction)
    sid_end = jnp.where(end, sid, n_streams)              # OOB drops
    new_tab = {
        "last_t": tab["last_t"].at[sid_end].set(
            jnp.broadcast_to(t[:, None], (n, N_DECAY)), mode="drop"),
        "w": tab["w"].at[sid_end].set(w, mode="drop"),
        "ls": tab["ls"].at[sid_end].set(ls, mode="drop"),
        "ss": tab["ss"].at[sid_end].set(ss, mode="drop"),
    }
    rows = inv if sample is None else inv[sample]
    atoms = {"w": w[rows], "ls": ls[rows], "ss": ss[rows]}
    return atoms, new_tab


def _stats(w, ls, ss):
    mu = jnp.where(w > 0, ls / jnp.maximum(w, 1e-12), 0.0)
    ex2 = jnp.where(w > 0, ss / jnp.maximum(w, 1e-12), 0.0)
    var = jnp.abs(ex2 - mu ** 2)
    return mu, var, jnp.sqrt(var)


# ---------------------------------------------------------------------------
# channel pass: stale opposite stats + SR recurrence
# ---------------------------------------------------------------------------
def channel_pass(bi_k, slots, dirs, ts, lens, own_atoms, n_slots,
                 order=None, dir_gather=None, sample=None, chunks: int = 1,
                 shard=None):
    """Cross-direction state for ONE bi key type.

    bi_k: the per-key-type slices of the bi table (each (n_slots, ...)).
    own_atoms: per-packet post-update atoms of the packet's own direction
    (original order, (n, N_DECAY) each).
    Returns (features pieces, updated bi_k).  ``order`` (stable sort by
    slot) and ``dir_gather`` (channel order -> (slot, dir, time) order,
    see ``_dir_interleave_perm``) are derived when not supplied.

    ``sample`` restricts the *emitted feature rows* to those
    original-order positions: the segmented scans and table store-backs
    always cover every packet (they carry the flow state), but the derived
    statistics (opposite-side stats, mag/radius/cov/pcc) and the feature
    stack are only materialised at the sampled rows — identical values to
    slicing the full output, row for row, since the per-row math is
    unchanged.

    The per-direction stale atoms AND last-residuals ride ONE stacked
    latest-value scan over ``(n, 2, N_DECAY, 4)`` (direction axis × lanes
    w/ls/ss/residual) — one scan dispatch where the unfused code paid four.
    """
    n = slots.shape[0]
    if order is None:
        order = jnp.argsort(slots, stable=True)
    inv = arith.invert_perm(order)
    sid = slots[order]
    d = dirs[order]
    t = ts[order]
    start, end = _segments(sid)
    if dir_gather is None:
        dir_gather = _dir_interleave_perm(start, end, d)

    own_w = own_atoms["w"][order]
    own_ls = own_atoms["ls"][order]
    own_ss = own_atoms["ss"][order]

    # --- residual vs own-direction mean (full width: SR consumes every row)
    mu_own, _, _ = _stats(own_w, own_ls, own_ss)
    lens_s = lens[order]
    r = lens_s[:, None] - mu_own                              # (n, ND)

    # --- ONE latest-value scan: latest same-channel packet per direction,
    # lanes = (w, ls, ss, residual); the table fallback is applied at
    # emission (atoms) / consumption (residual) time ---
    lanes = jnp.stack([own_w, own_ls, own_ss, r], axis=-1)    # (n, ND, 4)
    latest = jnp.broadcast_to(lanes[:, None],
                              (n, 2) + lanes.shape[1:])       # (n, 2, ND, 4)
    per_dir = jnp.stack([d == 0, d == 1], axis=1)             # (n, 2)
    found, val = seg_last_scan(start, per_dir, latest,
                               chunks=chunks, shard=shard)
    found0, found1 = found[:, 0], found[:, 1]                 # (n, 1, 1)
    val0, val1 = val[:, 0, :, :3], val[:, 1, :, :3]           # (n, ND, 3)
    tabv = jnp.stack([bi_k["w"], bi_k["ls"], bi_k["ss"]], axis=-1)

    def latest_res(X):
        fnd = found[:, X, :, 0]                               # (n, 1)
        return jnp.where(fnd, val[:, X, :, 3],
                         bi_k["res_last"][:, X][sid])

    r0 = latest_res(0)
    r1 = latest_res(1)
    r_opp = jnp.where((d == 0)[:, None], r1, r0)

    # --- SR recurrence over the whole channel (both directions) ---
    t_prev = jnp.concatenate([t[:1], t[:-1]])
    sr_lt_tab = bi_k["sr_last_t"][sid]                        # (n, ND)
    fresh = sr_lt_tab < 0.0
    dt = jnp.where(start[:, None],
                   jnp.where(fresh, 0.0, t[:, None] - sr_lt_tab),
                   (t - t_prev)[:, None])
    dsr = jnp.exp2(-_LAM[None, :] * jnp.maximum(dt, 0.0))
    dsr = jnp.where(start[:, None] & fresh, 0.0, dsr)
    x_sr = r * r_opp
    x_sr = jnp.where(start[:, None], x_sr + dsr * bi_k["sr"][sid], x_sr)
    sr = seg_linear_scan(start, dsr, x_sr, chunks=chunks, shard=shard)

    # --- bidirectional stats, emitted at the requested rows only ---
    def emit(rows):
        sel = (lambda a: a) if rows is None else (lambda a: a[rows])
        dr = sel(d)
        ow, ols, oss = sel(own_w), sel(own_ls), sel(own_ss)
        v0 = jnp.where(sel(found0), sel(val0), tabv[:, 0][sel(sid)])
        v1 = jnp.where(sel(found1), sel(val1), tabv[:, 1][sel(sid)])
        opp = jnp.where((dr == 0)[:, None, None], v1, v0)     # (m,ND,3)
        opp_w, opp_ls, opp_ss = opp[..., 0], opp[..., 1], opp[..., 2]
        mu_o, var_o, sig_o = _stats(ow, ols, oss)
        mu_p, var_p, sig_p = _stats(opp_w, opp_ls, opp_ss)
        mag = jnp.sqrt(mu_o ** 2 + mu_p ** 2)
        rad = jnp.sqrt(var_o ** 2 + var_p ** 2)
        wsum = ow + opp_w
        cov = jnp.where(wsum > 0, sel(sr) / jnp.maximum(wsum, 1e-12), 0.0)
        sden = sig_o * sig_p
        pcc = jnp.where(sden > 0, cov / jnp.maximum(sden, 1e-12), 0.0)
        return jnp.stack([ow, mu_o, sig_o, mag, rad, cov, pcc],
                         axis=-1)                             # (m, ND, 7)

    feats = emit(None)[inv] if sample is None else emit(inv[sample])

    # --- store-back (segment ends; res_last per direction: last of each) ---
    sid_end = jnp.where(end, sid, n_slots)
    new_bi = dict(bi_k)
    new_bi["sr"] = bi_k["sr"].at[sid_end].set(sr, mode="drop")
    new_bi["sr_last_t"] = bi_k["sr_last_t"].at[sid_end].set(
        jnp.broadcast_to(t[:, None], sr.shape), mode="drop")
    # last residual of each (channel, direction): last occurrence of the
    # composite key sid*2+d (unique per (segment, dir) since segments are
    # channel-contiguous) — the derived directional permutation IS the
    # stable sort by that key, so take its segment ends (no re-sort).
    k2s = (sid * 2 + d)[dir_gather]
    _, end2 = _segments(k2s)
    sid2_end = jnp.where(end2, k2s // 2, n_slots)
    d2 = k2s % 2
    new_bi["res_last"] = new_bi["res_last"].at[sid2_end, d2].set(
        r[dir_gather], mode="drop")
    return feats, new_bi


def _bi_key_pass(tabs, slots, dirs, ts, lens, n_slots, sample=None,
                 chunks: int = 1, shard=None):
    """Full bidirectional update for ONE bi key type with ONE argsort.

    tabs: the per-key slices of ``state["bi"]`` (last_t/w/ls/ss
    (n_slots, 2, ND); sr/sr_last_t (n_slots, ND); res_last (n_slots, 2, ND)).
    The channel sort (slot, time) is computed once; the directional stream
    order (slot, dir, time) the atom update needs is derived from it with
    segmented cumsum ranks, and the ``res_last`` store-back reuses the same
    derived permutation.  Returns (bi features (n|m, ND, 7), updated tabs);
    ``sample`` restricts the emitted feature rows (state is always full).
    """
    order = jnp.argsort(slots, stable=True)
    sid = slots[order]
    d_s = dirs[order]
    start, end = _segments(sid)
    dir_gather = _dir_interleave_perm(start, end, d_s)
    order_dir = order[dir_gather]

    # directional streams: stream id = slot*2 + dir; table layout
    # (n_slots, 2, ND) reshapes to that row id — a view, no data movement
    tab = {f: tabs[f].reshape(2 * n_slots, N_DECAY)
           for f in ("last_t", "w", "ls", "ss")}
    atoms, new_tab = stream_pass(tab, slots * 2 + dirs, ts, lens,
                                 2 * n_slots, order=order_dir,
                                 chunks=chunks, shard=shard)
    # stale-opposite fallback must be the PRE-batch table values
    bi_k_pre = {f: tabs[f] for f in
                ("sr", "sr_last_t", "res_last", "w", "ls", "ss")}
    fts, upd = channel_pass(bi_k_pre, slots, dirs, ts, lens, atoms, n_slots,
                            order=order, dir_gather=dir_gather,
                            sample=sample, chunks=chunks, shard=shard)
    new_tabs = {f: new_tab[f].reshape(n_slots, 2, N_DECAY)
                for f in ("last_t", "w", "ls", "ss")}
    new_tabs.update({f: upd[f] for f in ("sr", "sr_last_t", "res_last")})
    return fts, new_tabs


def _process_parallel_impl(state: Dict, pkts: Dict[str, jax.Array],
                           sample_idx=None, chunks: int = 1,
                           shard=None) -> Tuple[Dict, jax.Array]:
    from repro.core.state import state_slots
    n_slots = state_slots(state)
    sl = packet_slots(pkts, n_slots)
    ts = pkts["ts"].astype(jnp.float32)
    lens = pkts["length"].astype(jnp.float32)
    n_real = ts.shape[0]

    if chunks > 1:
        # equal-size chunks need n % chunks == 0: pad with sentinel-slot
        # packets that sort AFTER every real stream (their own segments at
        # the tail), never store back (OOB rows drop), and are never
        # emitted (feature rows are gathered for real packets only)
        pad = (-n_real) % chunks
        if pad:
            sl = {k: jnp.pad(v, (0, pad),
                             constant_values=0 if k == "dir" else n_slots)
                  for k, v in sl.items()}
            ts = jnp.pad(ts, (0, pad), mode="edge")   # keep ts monotone
            lens = jnp.pad(lens, (0, pad))
            if sample_idx is None:
                sample_idx = jnp.arange(n_real)
    n = n_real if sample_idx is None else sample_idx.shape[0]

    # ---- unidirectional: both key types vmapped over the stacked tables ----
    uni_ids = jnp.stack([sl[k] for k in ("src_mac_ip", "src_ip")])
    uni_tab = {f: state["uni"][f] for f in ("last_t", "w", "ls", "ss")}
    atoms, new_uni_tab = jax.vmap(
        lambda tab, ids: stream_pass(tab, ids, ts, lens, n_slots,
                                     sample=sample_idx, chunks=chunks,
                                     shard=shard)
    )(uni_tab, uni_ids)
    mu, _, sig = _stats(atoms["w"], atoms["ls"], atoms["ss"])
    uni_feats = jnp.stack([atoms["w"], mu, sig], axis=-1)    # (2, n|m, ND, 3)

    # ---- bidirectional: both key types vmapped, one argsort each ----
    bi_slots = jnp.stack([sl[k] for k in ("channel", "socket")])
    bi_tabs = {f: state["bi"][f] for f in
               ("last_t", "w", "ls", "ss", "sr", "sr_last_t", "res_last")}
    bi_feats, new_bi_tabs = jax.vmap(
        lambda tabs, s: _bi_key_pass(tabs, s, sl["dir"], ts, lens, n_slots,
                                     sample=sample_idx, chunks=chunks,
                                     shard=shard)
    )(bi_tabs, bi_slots)                                     # (2, n|m, ND, 7)

    out = jnp.concatenate([
        jnp.moveaxis(uni_feats, 0, 1).reshape(n, -1),
        jnp.moveaxis(bi_feats, 0, 1).reshape(n, -1)], axis=-1)
    new_state = {"uni": {**new_uni_tab, "rr": state["uni"]["rr"]},
                 "bi": {**new_bi_tabs, "rr": state["bi"]["rr"]}}
    return new_state, out


def process_parallel_sampled(state: Dict, pkts: Dict[str, jax.Array],
                             sample_idx: jax.Array) -> Tuple[Dict, jax.Array]:
    """Exact-mode FC where only ``sample_idx``'s feature rows are emitted.

    The flow-table update still covers every packet (same new state as
    :func:`process_parallel`, to compiler-refusion ulp noise); the emitted
    rows equal ``process_parallel(...)[1][sample_idx]`` to the same noise
    — the per-row math is the same, it just never materialises the
    unsampled rows.  Built for the
    fused serving step (serving/fused.py), which samples records *after*
    feature computation exactly as the paper prescribes, so packets that
    close no epoch never pay the statistics-assembly cost.  Unjitted: the
    caller fuses it into its own jit.
    """
    return _process_parallel_impl(state, pkts, sample_idx)


process_parallel = jax.jit(_process_parallel_impl,
                           static_argnames=("chunks", "shard"))
process_parallel.__doc__ = (
    "Exact-mode Peregrine FC via segmented scans. Same I/O as "
    "``process_serial(..., mode='exact')``.")
