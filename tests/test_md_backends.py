"""MD scoring backends: einsum vs fused-Pallas parity on every attack
generator, per-chunk streaming-score equality, and the train-time RMSE-pass
dispatch (repro/detection/md_backends.py, DESIGN.md §3)."""
import jax
import numpy as np
import pytest

from repro.core import compute_features, init_state
from repro.detection import (available_md_backends, resolve_md_backend,
                             score_kitnet, score_records, train_kitnet)
from repro.serving import DetectionService
from repro.traffic import ATTACKS, attack_trace, benign_trace, synth_trace, to_jnp

N_SLOTS = 2048


def _feats(trace):
    _, f = compute_features(init_state(N_SLOTS), to_jnp(trace),
                            backend="scan")
    return np.asarray(f)


@pytest.fixture(scope="module")
def net():
    """One KitNET fitted on benign features (the deployed object both
    backends must agree on)."""
    tr = benign_trace(1500, 8.0, np.random.default_rng(0))
    return train_kitnet(_feats(tr)[::4], seed=0)


def test_registry_and_aliases():
    assert available_md_backends() == ("einsum", "pallas")
    assert resolve_md_backend("kernel") == "pallas"
    assert resolve_md_backend("batched") == "einsum"
    with pytest.raises(ValueError, match="unknown MD backend"):
        resolve_md_backend("nope")


def test_unknown_md_options_rejected(net):
    """Misspelled/inapplicable md_kw options raise instead of silently
    measuring the defaults."""
    feats = np.zeros((4, 80), np.float32)
    with pytest.raises(TypeError, match="unexpected options"):
        score_records(net, feats, backend="pallas", block=256)  # typo of bb
    with pytest.raises(TypeError, match="unexpected options"):
        score_records(net, feats, backend="einsum", bb=256)
    with pytest.raises(TypeError, match="unexpected options"):
        DetectionService(n_slots=64, md_backend="pallas",
                         md_kw={"block": 256})


@pytest.mark.parametrize("attack", sorted(ATTACKS))
def test_einsum_pallas_score_parity(net, attack):
    """score_records(backend="pallas") tracks the einsum reference to
    ≤1e-5 on the feature distribution of every attack generator."""
    feats = _feats(attack_trace(attack, 600, 0.0, 10.0, seed=1))
    s_e = score_records(net, feats, backend="einsum")
    s_p = score_records(net, feats, backend="pallas")
    assert np.isfinite(s_e).all() and np.isfinite(s_p).all()
    np.testing.assert_allclose(s_p, s_e, atol=1e-5, rtol=1e-5)
    # the einsum backend IS the historical score_kitnet path
    np.testing.assert_array_equal(s_e, score_kitnet(net, feats))


def test_pallas_scores_batch_independent(net):
    """Per-record scores must not depend on batch composition — the
    property that makes per-chunk streaming scoring exact."""
    feats = _feats(attack_trace("mirai", 400, 0.0, 10.0, seed=2))
    one = score_records(net, feats, backend="pallas")
    chunked = np.concatenate([
        score_records(net, feats[i:i + 37], backend="pallas")
        for i in range(0, len(feats), 37)])
    np.testing.assert_array_equal(one, chunked)


def test_train_kitnet_md_backend_dispatch():
    """train_kitnet's training-set RMSE pass runs through the selected
    backend; the resulting nets score equivalently (≤1e-5)."""
    rng = np.random.default_rng(3)
    feats = rng.random((600, 80)).astype(np.float32)
    n_e = train_kitnet(feats, seed=0)
    n_p = train_kitnet(feats, seed=0, md_backend="pallas",
                       md_kw={"bb": 64})
    np.testing.assert_allclose(np.asarray(n_p.out_min),
                               np.asarray(n_e.out_min), atol=1e-5)
    np.testing.assert_allclose(np.asarray(n_p.out_max),
                               np.asarray(n_e.out_max), atol=1e-5)
    batch = rng.random((100, 80)).astype(np.float32) * 2.0
    np.testing.assert_allclose(score_records(n_p, batch, backend="pallas"),
                               score_records(n_e, batch, backend="einsum"),
                               atol=1e-5, rtol=1e-5)


def test_process_stream_chunked_equals_one_batch_pallas_md():
    """Per-chunk MD scoring (pallas backend, serial-semantics FC): chunked
    global indices, scores, and alarms are bit-identical to one-batch."""
    data = synth_trace("mirai", n_train=1024, n_benign_eval=512,
                       n_attack=512, seed=4)
    svc = DetectionService(epoch=64, n_slots=1024, mode="exact",
                           backend="serial", md_backend="pallas",
                           md_kw={"bb": 32})   # MD flags route via md_kw
    assert svc.md_backend == "pallas"
    svc.observe_stream(data["train"], chunk=256)
    svc.fit(fpr=0.05)
    snap_state = jax.tree_util.tree_map(jax.numpy.copy, svc.state)  # fused steps donate
    snap_count = svc.pkt_count

    idx1, s1, a1 = svc.process(data["eval"])
    svc.state, svc.pkt_count = snap_state, snap_count
    # uneven chunking so epoch boundaries straddle chunk boundaries
    idx2, s2, a2 = svc.process_stream(data["eval"], chunk=200)

    np.testing.assert_array_equal(idx1, idx2)
    np.testing.assert_array_equal(s1, s2)
    np.testing.assert_array_equal(a1, a2)


def test_kitnet_ensemble_interpret_env_read_at_call_time(monkeypatch):
    """Regression (kernels/ops.py): the kitnet_ensemble wrapper resolves
    interpret=None from REPRO_PALLAS_COMPILE per CALL, and an explicit
    interpret= always wins over the environment."""
    from repro.kernels import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.uniform(ks[0], (13, 3, 6))
    w1 = jax.random.normal(ks[1], (3, 6, 4)) * 0.3
    b1 = jax.random.normal(ks[2], (3, 4)) * 0.1
    w2 = jax.random.normal(ks[3], (3, 4, 6)) * 0.3
    b2 = jax.random.normal(ks[4], (3, 6)) * 0.1
    mask = (jax.random.uniform(ks[0], (3, 6)) > 0.2).astype(np.float32)

    monkeypatch.delenv("REPRO_PALLAS_COMPILE", raising=False)
    assert ops.interpret_default() is True
    r_env = ops.kitnet_ensemble(x, w1, b1, w2, b2, mask, bb=8)
    # flipping the env var after import must not require a re-import:
    # explicit interpret=True stays CPU-safe while the env requests compile
    monkeypatch.setenv("REPRO_PALLAS_COMPILE", "1")
    assert ops.interpret_default() is False
    r_exp = ops.kitnet_ensemble(x, w1, b1, w2, b2, mask, bb=8,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(r_env), np.asarray(r_exp))
    want = ref.kitnet_ensemble_ref(x, w1, b1, w2, b2, mask)
    np.testing.assert_allclose(np.asarray(r_env), np.asarray(want),
                               atol=1e-6)
