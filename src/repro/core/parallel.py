"""TPU-native Peregrine feature computation: segmented associative scans.

The switch updates flow state one packet at a time.  On TPU we exploit that
the decayed-atom update  A_i = delta_i * A_{i-1} + x_i  is a *linear
first-order recurrence*, hence associative:

    (s2, a2) o (s1, a1) = (s1*s2, a1*s2 + a2)

so a whole packet batch is processed in O(log n) depth with
``jax.lax.associative_scan``, *segmented by flow* (sort by stream id, stable,
which preserves time order inside each stream).  Cross-direction state
(stale opposite-direction statistics, last-residual for SR) uses a segmented
"latest-value" scan, which is also associative.

Semantics are bit-for-bit the serial oracle's ``exact`` mode (tested to
float tolerance); the round-robin ``switch`` mode is inherently per-packet
serial and stays on the oracle path.

A batch pays ONE stable argsort per key type (vmapped over the stacked
tables: one sort primitive for the two uni keys, one for the two bi keys).
Everything else is derived: the bidirectional (slot, dir, time) stream
order comes from the (slot, time) channel sort via segmented cumsum ranks
(``_dir_interleave_perm``), and the ``res_last`` store-back reuses that
same permutation instead of re-sorting by the composite key —
``tests/test_fused.py`` pins the sort count at ≤ 4.

``process_parallel_sampled`` is the record-sampled variant for the fused
serving step (DESIGN.md §8): flow-state updates cover every packet, but
feature statistics are only materialised at the sampled rows.

Requires ``pkts["ts"]`` sorted ascending (streams are time-ordered).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import arith
from repro.core.state import (
    LAMBDAS, N_BI, N_DECAY, N_UNI, packet_slots,
)

_LAM = jnp.asarray(LAMBDAS, jnp.float32)


# ---------------------------------------------------------------------------
# segmented-scan primitives
# ---------------------------------------------------------------------------
def seg_linear_scan(seg_start, delta, x):
    """Segmented A_i = delta_i * A_{i-1} + x_i (A resets at segment starts).

    seg_start: (n,) bool; delta, x: (n, ...) broadcastable. Returns A (n, ...).
    """
    f = seg_start
    while f.ndim < delta.ndim:
        f = f[..., None]
    f = jnp.broadcast_to(f, delta.shape)

    def combine(l, r):
        fl, sl, al = l
        fr, sr, ar = r
        return (fl | fr,
                jnp.where(fr, sr, sl * sr),
                jnp.where(fr, ar, al * sr + ar))

    _, _, a = jax.lax.associative_scan(combine, (f, delta, x), axis=0)
    return a


def seg_last_scan(seg_start, valid, value):
    """Segmented latest-valid-value (inclusive). Returns (found, last_value).

    ``found[i]`` False means no valid element yet in i's segment.
    """
    f = seg_start
    v = valid
    while f.ndim < value.ndim:
        f = f[..., None]
        v = v[..., None]
    f = jnp.broadcast_to(f, value.shape)
    v = jnp.broadcast_to(v, value.shape)

    def combine(l, r):
        fl, vl, xl = l
        fr, vr, xr = r
        found = jnp.where(fr, vr, vl | vr)
        # a fresh segment with no valid element must contribute an explicit
        # zero: ``xr * 0`` would propagate NaN/inf from invalid rows
        val = jnp.where(fr, jnp.where(vr, xr, jnp.zeros_like(xr)),
                        jnp.where(vr, xr, xl))
        return (fl | fr, found, val)

    _, found, val = jax.lax.associative_scan(combine, (f, v, value), axis=0)
    return found, val


def _segments(sorted_ids):
    n = sorted_ids.shape[0]
    start = jnp.concatenate([jnp.ones((1,), bool),
                             sorted_ids[1:] != sorted_ids[:-1]])
    end = jnp.concatenate([sorted_ids[1:] != sorted_ids[:-1],
                           jnp.ones((1,), bool)])
    return start, end


def _dir_interleave_perm(start, end, d):
    """Derive the (slot, dir, time) permutation from the (slot, time) sort.

    Given segment markers of the channel-sorted order and the per-element
    direction bits ``d``, returns ``gather`` such that ``X[gather]`` is the
    stable sort by the composite key ``slot*2 + dir`` — computed with
    segmented cumsum ranks in O(n), so the batch pays ONE argsort per key
    type instead of re-sorting for the directional view.
    """
    n = d.shape[0]
    ar = jnp.arange(n)
    seg_first = jax.lax.cummax(jnp.where(start, ar, -1))
    seg_last = jnp.flip(jax.lax.cummin(jnp.flip(jnp.where(end, ar, n))))
    d0 = (d == 0).astype(ar.dtype)
    pref0 = jnp.cumsum(d0)                  # inclusive dir-0 count
    excl0 = pref0 - d0
    base0 = excl0[seg_first]
    n0_seg = pref0[seg_last] - base0        # dir-0 population of the segment
    rank0 = excl0 - base0
    d1 = 1 - d0
    excl1 = jnp.cumsum(d1) - d1
    rank1 = excl1 - excl1[seg_first]
    pos = seg_first + jnp.where(d == 0, rank0, n0_seg + rank1)
    return jnp.zeros_like(pos).at[pos].set(ar)


# ---------------------------------------------------------------------------
# one directional stream table pass
# ---------------------------------------------------------------------------
def stream_pass(tab, stream_ids, ts, lens, n_streams, order=None,
                sample=None):
    """Vectorised decayed-atom update for one table of streams.

    tab: {"last_t","w","ls","ss"} each (n_streams, N_DECAY).
    stream_ids/ts/lens: (n,). Returns (per-packet atoms dict in ORIGINAL
    order, updated table).  ``order`` is the stable sort by stream id; pass
    it when already available (derived or shared) to avoid a re-sort.
    ``sample`` restricts the returned atoms to those original-order rows
    (the table update always covers every packet) — the fused serving step
    only ever reads the sampled records, so the full-width gather back to
    packet order is skipped.
    """
    n = stream_ids.shape[0]
    if order is None:
        order = jnp.argsort(stream_ids, stable=True)
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(n))
    sid = stream_ids[order]
    t = ts[order]
    x = lens[order]
    start, end = _segments(sid)

    # per-packet decay: dt to previous packet in stream (table last_t at start)
    t_prev_in = jnp.concatenate([t[:1], t[:-1]])
    last_t_tab = tab["last_t"][sid]                       # (n, N_DECAY)
    fresh = last_t_tab < 0.0
    dt = jnp.where(start[:, None],
                   jnp.where(fresh, 0.0, t[:, None] - last_t_tab),
                   (t - t_prev_in)[:, None])
    dt = jnp.maximum(dt, 0.0)
    delta = jnp.exp2(-_LAM[None, :] * dt)
    delta = jnp.where(start[:, None] & fresh, 0.0, delta)

    def scan_atom(x_inc):
        """x_inc: (n, N_DECAY) per-packet increment."""
        return seg_linear_scan(start, delta, x_inc)

    # fold table carry into the first element: A_1 = delta_1*A_tab + x_1
    def with_carry(tab_a, x_inc):
        x0 = jnp.where(start[:, None], x_inc + delta * tab_a[sid], x_inc)
        return scan_atom(x0)

    ones = jnp.ones((n, N_DECAY))
    w = with_carry(tab["w"], ones)
    ls = with_carry(tab["ls"], jnp.broadcast_to(x[:, None], (n, N_DECAY)))
    ss = with_carry(tab["ss"], jnp.broadcast_to((x ** 2)[:, None], (n, N_DECAY)))

    # store back last element of each segment (indices unique by construction)
    sid_end = jnp.where(end, sid, n_streams)              # OOB drops
    new_tab = {
        "last_t": tab["last_t"].at[sid_end].set(
            jnp.broadcast_to(t[:, None], (n, N_DECAY)), mode="drop"),
        "w": tab["w"].at[sid_end].set(w, mode="drop"),
        "ls": tab["ls"].at[sid_end].set(ls, mode="drop"),
        "ss": tab["ss"].at[sid_end].set(ss, mode="drop"),
    }
    rows = inv if sample is None else inv[sample]
    atoms = {"w": w[rows], "ls": ls[rows], "ss": ss[rows]}
    return atoms, new_tab


def _stats(w, ls, ss):
    mu = jnp.where(w > 0, ls / jnp.maximum(w, 1e-12), 0.0)
    ex2 = jnp.where(w > 0, ss / jnp.maximum(w, 1e-12), 0.0)
    var = jnp.abs(ex2 - mu ** 2)
    return mu, var, jnp.sqrt(var)


# ---------------------------------------------------------------------------
# channel pass: stale opposite stats + SR recurrence
# ---------------------------------------------------------------------------
def channel_pass(bi_k, slots, dirs, ts, lens, own_atoms, n_slots,
                 order=None, dir_gather=None, sample=None):
    """Cross-direction state for ONE bi key type.

    bi_k: the per-key-type slices of the bi table (each (n_slots, ...)).
    own_atoms: per-packet post-update atoms of the packet's own direction
    (original order, (n, N_DECAY) each).
    Returns (features pieces, updated bi_k).  ``order`` (stable sort by
    slot) and ``dir_gather`` (channel order -> (slot, dir, time) order,
    see ``_dir_interleave_perm``) are derived when not supplied.

    ``sample`` restricts the *emitted feature rows* to those
    original-order positions: the segmented scans and table store-backs
    always cover every packet (they carry the flow state), but the derived
    statistics (opposite-side stats, mag/radius/cov/pcc) and the feature
    stack are only materialised at the sampled rows — identical values to
    slicing the full output, row for row, since the per-row math is
    unchanged.
    """
    n = slots.shape[0]
    if order is None:
        order = jnp.argsort(slots, stable=True)
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(n))
    sid = slots[order]
    d = dirs[order]
    t = ts[order]
    start, end = _segments(sid)
    if dir_gather is None:
        dir_gather = _dir_interleave_perm(start, end, d)

    own_w = own_atoms["w"][order]
    own_ls = own_atoms["ls"][order]
    own_ss = own_atoms["ss"][order]

    # --- stale opposite-direction atoms: latest same-channel opposite pkt
    # (the scans run over every packet; the table fallback is applied at
    # emission time so it is only gathered for emitted rows) ---
    stacked = jnp.stack([own_w, own_ls, own_ss], axis=-1)      # (n,ND,3)
    found0, val0 = seg_last_scan(start, d == 0, stacked)
    found1, val1 = seg_last_scan(start, d == 1, stacked)
    tabv = jnp.stack([bi_k["w"], bi_k["ls"], bi_k["ss"]], axis=-1)  # (ns,2,ND,3)

    # --- residuals (full width: the SR recurrence consumes every row) ---
    mu_own, _, _ = _stats(own_w, own_ls, own_ss)
    lens_s = lens[order]
    r = lens_s[:, None] - mu_own                              # (n, ND)

    def latest_res(X, tab_res):
        valid = d == X
        found, val = seg_last_scan(start, valid, r)
        return jnp.where(found, val, tab_res[sid])

    r0 = latest_res(0, bi_k["res_last"][:, 0])
    r1 = latest_res(1, bi_k["res_last"][:, 1])
    r_opp = jnp.where((d == 0)[:, None], r1, r0)

    # --- SR recurrence over the whole channel (both directions) ---
    t_prev = jnp.concatenate([t[:1], t[:-1]])
    sr_lt_tab = bi_k["sr_last_t"][sid]                        # (n, ND)
    fresh = sr_lt_tab < 0.0
    dt = jnp.where(start[:, None],
                   jnp.where(fresh, 0.0, t[:, None] - sr_lt_tab),
                   (t - t_prev)[:, None])
    dsr = jnp.exp2(-_LAM[None, :] * jnp.maximum(dt, 0.0))
    dsr = jnp.where(start[:, None] & fresh, 0.0, dsr)
    x_sr = r * r_opp
    x_sr = jnp.where(start[:, None], x_sr + dsr * bi_k["sr"][sid], x_sr)
    sr = seg_linear_scan(start, dsr, x_sr)

    # --- bidirectional stats, emitted at the requested rows only ---
    def emit(rows):
        sel = (lambda a: a) if rows is None else (lambda a: a[rows])
        dr = sel(d)
        ow, ols, oss = sel(own_w), sel(own_ls), sel(own_ss)
        v0 = jnp.where(sel(found0), sel(val0), tabv[:, 0][sel(sid)])
        v1 = jnp.where(sel(found1), sel(val1), tabv[:, 1][sel(sid)])
        opp = jnp.where((dr == 0)[:, None, None], v1, v0)     # (m,ND,3)
        opp_w, opp_ls, opp_ss = opp[..., 0], opp[..., 1], opp[..., 2]
        mu_o, var_o, sig_o = _stats(ow, ols, oss)
        mu_p, var_p, sig_p = _stats(opp_w, opp_ls, opp_ss)
        mag = jnp.sqrt(mu_o ** 2 + mu_p ** 2)
        rad = jnp.sqrt(var_o ** 2 + var_p ** 2)
        wsum = ow + opp_w
        cov = jnp.where(wsum > 0, sel(sr) / jnp.maximum(wsum, 1e-12), 0.0)
        sden = sig_o * sig_p
        pcc = jnp.where(sden > 0, cov / jnp.maximum(sden, 1e-12), 0.0)
        return jnp.stack([ow, mu_o, sig_o, mag, rad, cov, pcc],
                         axis=-1)                             # (m, ND, 7)

    feats = emit(None)[inv] if sample is None else emit(inv[sample])

    # --- store-back (segment ends; res_last per direction: last of each) ---
    sid_end = jnp.where(end, sid, n_slots)
    new_bi = dict(bi_k)
    new_bi["sr"] = bi_k["sr"].at[sid_end].set(sr, mode="drop")
    new_bi["sr_last_t"] = bi_k["sr_last_t"].at[sid_end].set(
        jnp.broadcast_to(t[:, None], sr.shape), mode="drop")
    # last residual of each (channel, direction): last occurrence of the
    # composite key sid*2+d (unique per (segment, dir) since segments are
    # channel-contiguous) — the derived directional permutation IS the
    # stable sort by that key, so take its segment ends (no re-sort).
    k2s = (sid * 2 + d)[dir_gather]
    _, end2 = _segments(k2s)
    sid2_end = jnp.where(end2, k2s // 2, n_slots)
    d2 = k2s % 2
    new_bi["res_last"] = new_bi["res_last"].at[sid2_end, d2].set(
        r[dir_gather], mode="drop")
    return feats, new_bi


def _bi_key_pass(tabs, slots, dirs, ts, lens, n_slots, sample=None):
    """Full bidirectional update for ONE bi key type with ONE argsort.

    tabs: the per-key slices of ``state["bi"]`` (last_t/w/ls/ss
    (n_slots, 2, ND); sr/sr_last_t (n_slots, ND); res_last (n_slots, 2, ND)).
    The channel sort (slot, time) is computed once; the directional stream
    order (slot, dir, time) the atom update needs is derived from it with
    segmented cumsum ranks, and the ``res_last`` store-back reuses the same
    derived permutation.  Returns (bi features (n|m, ND, 7), updated tabs);
    ``sample`` restricts the emitted feature rows (state is always full).
    """
    order = jnp.argsort(slots, stable=True)
    sid = slots[order]
    d_s = dirs[order]
    start, end = _segments(sid)
    dir_gather = _dir_interleave_perm(start, end, d_s)
    order_dir = order[dir_gather]

    # directional streams: stream id = slot*2 + dir; table layout
    # (n_slots, 2, ND) reshapes to that row id — a view, no data movement
    tab = {f: tabs[f].reshape(2 * n_slots, N_DECAY)
           for f in ("last_t", "w", "ls", "ss")}
    atoms, new_tab = stream_pass(tab, slots * 2 + dirs, ts, lens,
                                 2 * n_slots, order=order_dir)
    # stale-opposite fallback must be the PRE-batch table values
    bi_k_pre = {f: tabs[f] for f in
                ("sr", "sr_last_t", "res_last", "w", "ls", "ss")}
    fts, upd = channel_pass(bi_k_pre, slots, dirs, ts, lens, atoms, n_slots,
                            order=order, dir_gather=dir_gather,
                            sample=sample)
    new_tabs = {f: new_tab[f].reshape(n_slots, 2, N_DECAY)
                for f in ("last_t", "w", "ls", "ss")}
    new_tabs.update({f: upd[f] for f in ("sr", "sr_last_t", "res_last")})
    return fts, new_tabs


def _process_parallel_impl(state: Dict, pkts: Dict[str, jax.Array],
                           sample_idx=None) -> Tuple[Dict, jax.Array]:
    from repro.core.state import state_slots
    n_slots = state_slots(state)
    sl = packet_slots(pkts, n_slots)
    ts = pkts["ts"].astype(jnp.float32)
    lens = pkts["length"].astype(jnp.float32)
    n = ts.shape[0] if sample_idx is None else sample_idx.shape[0]

    # ---- unidirectional: both key types vmapped over the stacked tables ----
    uni_ids = jnp.stack([sl[k] for k in ("src_mac_ip", "src_ip")])
    uni_tab = {f: state["uni"][f] for f in ("last_t", "w", "ls", "ss")}
    atoms, new_uni_tab = jax.vmap(
        lambda tab, ids: stream_pass(tab, ids, ts, lens, n_slots,
                                     sample=sample_idx)
    )(uni_tab, uni_ids)
    mu, _, sig = _stats(atoms["w"], atoms["ls"], atoms["ss"])
    uni_feats = jnp.stack([atoms["w"], mu, sig], axis=-1)    # (2, n|m, ND, 3)

    # ---- bidirectional: both key types vmapped, one argsort each ----
    bi_slots = jnp.stack([sl[k] for k in ("channel", "socket")])
    bi_tabs = {f: state["bi"][f] for f in
               ("last_t", "w", "ls", "ss", "sr", "sr_last_t", "res_last")}
    bi_feats, new_bi_tabs = jax.vmap(
        lambda tabs, s: _bi_key_pass(tabs, s, sl["dir"], ts, lens, n_slots,
                                     sample=sample_idx)
    )(bi_tabs, bi_slots)                                     # (2, n|m, ND, 7)

    out = jnp.concatenate([
        jnp.moveaxis(uni_feats, 0, 1).reshape(n, -1),
        jnp.moveaxis(bi_feats, 0, 1).reshape(n, -1)], axis=-1)
    new_state = {"uni": {**new_uni_tab, "rr": state["uni"]["rr"]},
                 "bi": {**new_bi_tabs, "rr": state["bi"]["rr"]}}
    return new_state, out


def process_parallel_sampled(state: Dict, pkts: Dict[str, jax.Array],
                             sample_idx: jax.Array) -> Tuple[Dict, jax.Array]:
    """Exact-mode FC where only ``sample_idx``'s feature rows are emitted.

    The flow-table update still covers every packet (identical new state to
    :func:`process_parallel`); the emitted rows are bit-identical to
    ``process_parallel(...)[1][sample_idx]`` — the per-row math is the
    same, it just never materialises the unsampled rows.  Built for the
    fused serving step (serving/fused.py), which samples records *after*
    feature computation exactly as the paper prescribes, so packets that
    close no epoch never pay the statistics-assembly cost.  Unjitted: the
    caller fuses it into its own jit.
    """
    return _process_parallel_impl(state, pkts, sample_idx)


process_parallel = jax.jit(_process_parallel_impl)
process_parallel.__doc__ = (
    "Exact-mode Peregrine FC via segmented scans. Same I/O as "
    "``process_serial(..., mode='exact')``.")
