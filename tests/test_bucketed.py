"""Bucketed mesh-parallel FC engine (core/bucketed.py): parity with the
flat scan backend across every attack generator and bucket count, ragged
batches, streaming continuity through DetectionService, the fused
record-sampled path, shard_map mesh placement, and the scan-fusion
primitive-count regressions (DESIGN.md §9).

Tolerance model: S=1 degenerates to the flat scan and must be
*bit-identical*.  S>1 reassociates the segmented combines at bucket cuts
(two-level scan), so raw atoms agree to a few ulp and cancellation-derived
columns (std/radius/cov) to the same envelope the scan backend itself is
held to against the serial oracle (tests/test_backends.py) — bucketed is
exactly as close to the serial oracle as scan is, which the oracle-parity
test pins directly.
"""
from unittest import mock

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FEATURE_NAMES, N_FEATURES, available_backends,
                        compute_features, init_state, process_bucketed,
                        resolve_backend)
from repro.core.backends import compute_features_sampled
from repro.traffic.generator import ATTACKS, benign_trace

N_PKTS = 256
N_SLOTS = 512

BUCKET_COUNTS = (1, 4, 16)

_PCC = [i for i, nm in enumerate(FEATURE_NAMES) if nm.endswith(":pcc")]
_NON_PCC = np.setdiff1d(np.arange(N_FEATURES), _PCC)


def _trace(attack: str, seed: int = 0, n: int = N_PKTS):
    """Benign background + one attack window, truncated to a fixed length
    so every parametrization shares one jit compilation per bucket count."""
    rng = np.random.default_rng(seed)
    ben = benign_trace(160, 6.0, rng)
    atk = ATTACKS[attack](120, 1.0, 5.0, rng)
    out = {k: np.concatenate([ben[k], atk[k]]) for k in ben}
    order = np.argsort(out["ts"], kind="stable")
    out = {k: v[order][:n] for k, v in out.items()}
    assert len(out["ts"]) == n, attack
    return {k: jnp.asarray(v) for k, v in out.items() if k != "label"}


@pytest.fixture(scope="module")
def scan_reference():
    cache = {}

    def get(attack):
        if attack not in cache:
            pk = _trace(attack)
            st, feats = compute_features(init_state(N_SLOTS), pk,
                                         backend="scan")
            cache[attack] = (pk, st, np.asarray(feats))
        return cache[attack]

    return get


@pytest.fixture(scope="module")
def serial_reference():
    cache = {}

    def get(attack):
        if attack not in cache:
            pk = _trace(attack)
            st, feats = compute_features(init_state(N_SLOTS), pk,
                                         backend="serial", mode="exact")
            cache[attack] = (pk, st, np.asarray(feats))
        return cache[attack]

    return get


# ---------------------------------------------------------------------------
# parity with the flat scan backend
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("buckets", BUCKET_COUNTS)
@pytest.mark.parametrize("attack", sorted(ATTACKS))
def test_bucketed_matches_scan(scan_reference, attack, buckets):
    """Features AND post-batch state track the flat scan: bit-identical at
    S=1 (the two-level path degenerates to one flat scan), a few-ulp
    reassociation envelope beyond (amplified only by the documented
    cancellation columns)."""
    pk, st_ref, f_ref = scan_reference(attack)
    st, f = compute_features(init_state(N_SLOTS), pk, backend="bucketed",
                             buckets=buckets)
    f = np.asarray(f)
    assert f.shape == (N_PKTS, N_FEATURES)
    assert np.isfinite(f).all()
    if buckets == 1:
        np.testing.assert_array_equal(f, f_ref, err_msg=attack)
    else:
        ok = np.abs(f - f_ref) <= (1.0 + 1e-3 * np.abs(f_ref))
        assert ok[:, _NON_PCC].all(), (attack, buckets)
        assert ok.mean() >= 0.995, (attack, buckets, ok.mean())
    for grp in ("uni", "bi"):
        for k in st_ref[grp]:
            a, b = np.asarray(st[grp][k]), np.asarray(st_ref[grp][k])
            if buckets == 1 or k == "rr":
                np.testing.assert_array_equal(
                    a, b, err_msg=f"{attack}/S={buckets}/{grp}/{k}")
            else:
                np.testing.assert_allclose(
                    a, b, rtol=1e-3, atol=1.0,
                    err_msg=f"{attack}/S={buckets}/{grp}/{k}")


@pytest.mark.parametrize("attack", sorted(ATTACKS))
def test_bucketed_matches_serial_oracle(serial_reference, attack):
    """Bucketed is held to the SAME serial-oracle envelope as the scan
    backend (test_backends.py): bucketing must not add error beyond the
    scan backend's own fp reassociation."""
    pk, st_ref, f_ref = serial_reference(attack)
    st, f = compute_features(init_state(N_SLOTS), pk, backend="bucketed",
                             buckets=4)
    f = np.asarray(f)
    ok = np.abs(f - f_ref) <= (1.0 + 1e-3 * np.abs(f_ref))
    assert ok[:, _NON_PCC].all(), attack
    assert ok.mean() >= 0.995, (attack, ok.mean())
    for grp in ("uni", "bi"):
        for k in st_ref[grp]:
            if k == "rr":
                continue
            np.testing.assert_allclose(
                np.asarray(st[grp][k]), np.asarray(st_ref[grp][k]),
                rtol=1e-3, atol=1.0, err_msg=f"{attack}/{grp}/{k}")


def test_bucketed_ragged_batch_padding():
    """n not divisible by S: sentinel-slot padding must neither leak into
    real flow state nor change the emitted row count."""
    pk = _trace("mirai", n=250)
    st_ref, f_ref = compute_features(init_state(N_SLOTS), pk,
                                     backend="scan")
    st, f = compute_features(init_state(N_SLOTS), pk, backend="bucketed",
                             buckets=16)                 # pad = 6
    f = np.asarray(f)
    assert f.shape == (250, N_FEATURES)
    ok = np.abs(f - np.asarray(f_ref)) <= (1.0 + 1e-3 * np.abs(f_ref))
    assert ok[:, _NON_PCC].all()
    for grp in ("uni", "bi"):
        for k in st_ref[grp]:
            np.testing.assert_allclose(
                np.asarray(st[grp][k]), np.asarray(st_ref[grp][k]),
                rtol=1e-3, atol=1.0, err_msg=f"{grp}/{k}")


# ---------------------------------------------------------------------------
# streaming + service integration
# ---------------------------------------------------------------------------
def test_bucketed_streaming_chunks_track_one_shot():
    """Chunked streaming with state carry tracks one-shot processing to
    the scan backend's cross-chunk tolerance (DESIGN.md §5: reduction
    order differs across chunk boundaries)."""
    pk = _trace("mirai")
    _, f_once = compute_features(init_state(N_SLOTS), pk,
                                 backend="bucketed", buckets=4)
    st = init_state(N_SLOTS)
    outs = []
    for i in range(0, N_PKTS, 64):
        chunk = {k: v[i:i + 64] for k, v in pk.items()}
        st, f = compute_features(st, chunk, backend="bucketed", buckets=4)
        outs.append(np.asarray(f))
    got, want = np.concatenate(outs), np.asarray(f_once)
    ok = np.abs(got - want) <= (1.0 + 1e-3 * np.abs(want))
    assert ok[:, _NON_PCC].all()
    assert ok.mean() >= 0.995


def test_detection_service_bucketed_stream_continuity():
    """DetectionService(backend='bucketed'): fused + staged paths agree,
    and chunked process_stream carries state/epoch accounting so record
    indices are identical to a one-batch run (scores to float tolerance —
    scan semantics, DESIGN.md §5)."""
    from repro.serving import DetectionService
    from repro.traffic import synth_trace

    data = synth_trace("mirai", n_train=768, n_benign_eval=256,
                       n_attack=256, seed=0)
    svc = DetectionService(epoch=32, n_slots=N_SLOTS, mode="exact",
                           backend="bucketed", buckets=4)
    svc.observe_stream(data["train"], chunk=256)
    svc.fit(fpr=0.05)
    assert svc.fused                     # exact mode defaults to fused
    ev = {k: v for k, v in data["eval"].items() if k != "label"}
    snap = jax.tree_util.tree_map(jnp.copy, svc.state)
    c0 = svc.pkt_count
    i1, s1, a1 = svc.process(ev, fused=True)
    assert len(i1) > 0
    svc.state, svc.pkt_count = jax.tree_util.tree_map(jnp.copy, snap), c0
    i2, s2, _ = svc.process(ev, fused=False)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-6)
    svc.state, svc.pkt_count = snap, c0
    i3, s3, _ = svc.process_stream(ev, chunk=96, fused=True)
    np.testing.assert_array_equal(i1, i3)
    np.testing.assert_allclose(s1, s3, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# fused record-sampled path
# ---------------------------------------------------------------------------
def test_bucketed_sampled_rows_match_full():
    """compute_features_sampled(backend='bucketed'): the same scans and
    store-backs run in both passes, so state matches to XLA-refusion ulp
    noise (the compiler fuses the scan combine differently depending on
    the emission subgraph — the scan backend has the identical envelope;
    the decayed residual-product sum ``sr`` reaches ~1e-5 relative) and
    emitted rows match full[idx] to the cancellation-column envelope."""
    pk = _trace("syn_dos")
    idx = jnp.asarray([5, 31, 63, 200, 255])
    st_f, full = compute_features(init_state(N_SLOTS), pk,
                                  backend="bucketed", buckets=4)
    st_s, rows = compute_features_sampled(init_state(N_SLOTS), pk, idx,
                                          backend="bucketed", buckets=4)
    for grp in ("uni", "bi"):
        for k in st_f[grp]:
            np.testing.assert_allclose(
                np.asarray(st_s[grp][k]), np.asarray(st_f[grp][k]),
                rtol=1e-4, atol=1e-3, err_msg=f"{grp}/{k}")
    want = np.asarray(full)[np.asarray(idx)]
    got = np.asarray(rows)
    ok = np.abs(got - want) <= (1.0 + 1e-3 * np.abs(want))
    assert ok[:, _NON_PCC].all()
    assert ok.mean() >= 0.995


def test_bucketed_sampled_is_registered():
    """The fused serving step must get the native record-sampled path —
    a bucketed service's fused jit never materialises unsampled rows."""
    from repro.core.backends import _SAMPLED
    assert "bucketed" in _SAMPLED


# ---------------------------------------------------------------------------
# mesh placement
# ---------------------------------------------------------------------------
def test_bucketed_under_mesh_rules_shard_map():
    """flow_shards binding + a bound mesh routes the local per-bucket
    scans through shard_map; with a 1-device mesh the computation is
    identical, so results must be bit-identical to the unplaced run."""
    from repro.core.bucketed import _resolve_placement
    from repro.distributed.sharding import set_mesh, use_rules

    pk = _trace("os_scan")
    _, f_ref = compute_features(init_state(N_SLOTS), pk,
                                backend="bucketed", buckets=4)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    with set_mesh(mesh):
        with use_rules({"flow_shards": "data"}):
            m, binding = _resolve_placement(4)
            assert m is not None and binding == "data"
            _, f = compute_features(init_state(N_SLOTS), pk,
                                    backend="bucketed", buckets=4)
    np.testing.assert_array_equal(np.asarray(f), np.asarray(f_ref))
    # unplaced fallbacks: no rules bound, and a rule naming a missing axis
    assert _resolve_placement(4) == (None, None)
    with set_mesh(mesh):
        with use_rules({"flow_shards": "nope"}):
            assert _resolve_placement(4) == (None, None)


def test_fused_step_cache_keyed_on_placement():
    """Regression: binding a mesh + flow_shards rule mid-stream must hand
    back a DIFFERENT fused step (the partitioned backends resolve their
    placement at trace time, so a cached single-device executable would
    silently keep running unplaced)."""
    from repro.serving.fused import make_fused_step
    from repro.distributed.sharding import set_mesh, use_rules

    unplaced = make_fused_step(backend="bucketed",
                               backend_kw={"buckets": 4}, epoch=32)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    with set_mesh(mesh):
        with use_rules({"flow_shards": "data"}):
            placed = make_fused_step(backend="bucketed",
                                     backend_kw={"buckets": 4}, epoch=32)
    assert placed is not unplaced
    # and re-resolving outside the context returns the unplaced step again
    assert make_fused_step(backend="bucketed", backend_kw={"buckets": 4},
                           epoch=32) is unplaced


# ---------------------------------------------------------------------------
# registry + error paths
# ---------------------------------------------------------------------------
def test_bucketed_registered_exact_only():
    assert "bucketed" in available_backends()
    assert resolve_backend("bucketed") == "bucketed"
    st = init_state(64)
    pk = _trace("syn_dos")
    with pytest.raises(ValueError, match="switch"):
        compute_features(st, pk, backend="bucketed", mode="switch")
    with pytest.raises(ValueError, match="buckets"):
        process_bucketed(st, pk, buckets=0)


# ---------------------------------------------------------------------------
# scan-fusion primitive counts (the perf contract of this engine)
# ---------------------------------------------------------------------------
def _count_sorts(jaxpr):
    c = 0
    for eq in jaxpr.eqns:
        if eq.primitive.name == "sort":
            c += 1
        for p in eq.params.values():
            for q in (p if isinstance(p, (list, tuple)) else (p,)):
                if hasattr(q, "jaxpr"):
                    c += _count_sorts(q.jaxpr)
    return c


def _dummy_batch(n=64):
    pk = {k: jnp.zeros((n,), jnp.int32)
          for k in ("src", "dst", "sport", "dport", "proto")}
    pk["ts"] = jnp.linspace(0.0, 1.0, n)
    pk["length"] = jnp.ones((n,))
    return pk


@pytest.mark.parametrize("chunks,max_scans", [(1, 4), (4, 8)])
def test_scan_fusion_primitive_counts(chunks, max_scans):
    """The fused pipeline pays ONE stacked associative scan per stream
    table (atoms w/ls/ss ride together), ONE latest-value scan per channel
    pass (both directions x atoms+residual lanes), and ONE SR scan — 4
    invocations per batch where the unfused code paid 11.  The bucketed
    two-level form doubles each (local scans + the O(S) tail-carry
    combine): ≤ 2 per stream table, as budgeted in DESIGN.md §9.  Sort
    primitives stay at ≤ 4 (one stable argsort per key type, vmapped) —
    bucket compaction derives from the existing sort, it never adds one."""
    from repro.core.parallel import _process_parallel_impl
    st = init_state(256)
    pk = _dummy_batch()
    with mock.patch.object(jax.lax, "associative_scan",
                           wraps=jax.lax.associative_scan) as m:
        jaxpr = jax.make_jaxpr(
            lambda s, p: _process_parallel_impl(s, p, chunks=chunks))(st, pk)
    assert m.call_count <= max_scans, m.call_count
    assert _count_sorts(jaxpr.jaxpr) <= 4
