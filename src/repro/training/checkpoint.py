"""Checkpointing: atomic, async-capable, elastic-restorable.

Layout per step:  <dir>/step_<n>/
    manifest.json   — treedef paths, shapes, dtypes, step, mesh shape
    arrays.npz      — all leaves (addressable host values)
    COMMIT          — written last; a checkpoint without it is invalid

Atomicity: everything is written into ``<dir>/.tmp_step_<n>`` and
``os.replace``d into place, so a crash mid-save never corrupts the latest
valid checkpoint.  ``save_async`` runs the serialisation on a worker thread
(double-buffered: we snapshot to host numpy before returning).

Elastic restore: arrays are loaded as full host values and ``device_put``
with whatever sharding the *new* mesh prescribes — restoring a checkpoint
onto a different mesh shape (elastic up/down-scaling) is therefore free at
this layer; tests cover 8 -> 4 -> 8 host-device remeshes.  (A true multi-host
deployment would shard the .npz per host; single-controller here.)
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) for path, _ in flat]
    return keys, [l for _, l in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state) -> str:
        keys, leaves, _ = _flatten(state)
        host = [np.asarray(l) for l in leaves]
        return self._write(step, keys, host)

    def save_async(self, step: int, state) -> None:
        self.wait()
        keys, leaves, _ = _flatten(state)
        host = [np.asarray(l) for l in leaves]       # snapshot before bg write
        self._thread = threading.Thread(
            target=self._write, args=(step, keys, host), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, keys: List[str], host: List[np.ndarray]) -> str:
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"a{i}": a for i, a in enumerate(host)})
        manifest = {
            "step": step, "time": time.time(),
            "keys": keys,
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, "COMMIT")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``target`` (a state pytree or
        eval_shape thereof). ``shardings``: optional matching pytree of
        NamedSharding for the (possibly different) current mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        data = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        keys_t, leaves_t, treedef = _flatten(target)
        by_key = {k: data[f"a{i}"] for i, k in enumerate(manifest["keys"])}
        out = []
        sh_flat = (jax.tree_util.tree_leaves(shardings)
                   if shardings is not None else [None] * len(leaves_t))
        for k, tgt, sh in zip(keys_t, leaves_t, sh_flat):
            arr = by_key[k]
            assert tuple(arr.shape) == tuple(tgt.shape), (k, arr.shape, tgt.shape)
            arr = arr.astype(tgt.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step
