"""Fault tolerance: failure injection + auto-resume, stragglers, elasticity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_arch, reduced
from repro.data import lm_batches
from repro.models import build_model
from repro.training import CheckpointManager, init_train_state, make_train_step
from repro.training.fault import FailureInjector, StragglerMonitor, resilient_loop

KEY = jax.random.PRNGKey(0)


def _setup():
    m = build_model(reduced(get_arch("gemma2-2b")))
    tc = TrainConfig(learning_rate=1e-3)
    state = init_train_state(m, tc, KEY)
    step = jax.jit(make_train_step(m, tc))
    batches = [{k: jnp.asarray(v) for k, v in b.items()}
               for b in lm_batches(m.cfg.vocab, 4, 16, 12, seed=4)]
    return state, step, batches


def test_resume_after_injected_failures(tmp_path):
    state, step, batches = _setup()
    # ground truth: uninterrupted run
    ref_state = state
    for b in batches:
        ref_state, ref_metrics = step(ref_state, b)

    ckpt = CheckpointManager(str(tmp_path / "ft"), keep=3)
    inj = FailureInjector(fail_at=[3, 7, 7 + 0])  # double failure at one step
    out = resilient_loop(step, state, batches, ckpt, ckpt_every=2,
                         injector=inj, max_restarts=5)
    assert out["restarts"] >= 2
    assert out["completed"] == len(batches)
    # final params identical to the uninterrupted run (resume is exact:
    # checkpoints cut at batch boundaries and the loop replays from there)
    for a, b in zip(jax.tree_util.tree_leaves(out["state"]["params"]),
                    jax.tree_util.tree_leaves(ref_state["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_too_many_failures_raises(tmp_path):
    state, step, batches = _setup()
    ckpt = CheckpointManager(str(tmp_path / "ft2"))
    inj = FailureInjector(fail_at=list(range(12)))

    class AlwaysFail(FailureInjector):
        def maybe_fail(self, step):
            raise RuntimeError("permanent failure")

    with pytest.raises(RuntimeError):
        resilient_loop(step, state, batches, ckpt, injector=AlwaysFail([]),
                       max_restarts=3)


def test_straggler_monitor():
    mon = StragglerMonitor(threshold=3.0)
    flagged = []
    for i, dt in enumerate([1.0, 1.1, 0.9, 1.0, 5.0, 1.0, 1.05]):
        if mon.record(i, dt):
            flagged.append(i)
    assert flagged == [4]
    # EWMA not poisoned by the straggler
    assert 0.8 < mon.ewma < 1.3


def test_elastic_restore_changes_nothing_on_host(tmp_path):
    """Restore with an explicit sharding argument (single-device here) is
    value-identical; multi-device elasticity is covered by
    test_distributed.py via subprocess meshes."""
    state, step, batches = _setup()
    mgr = CheckpointManager(str(tmp_path / "el"))
    state, _ = step(state, batches[0])
    mgr.save(1, state)
    sh = jax.tree_util.tree_map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), state)
    restored, _ = mgr.restore(jax.eval_shape(lambda: state), shardings=sh)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
