"""Fault tolerance: resumable train loop, failure injection, straggler watch.

``resilient_loop`` is the production loop skeleton: checkpoint every
``ckpt_every`` steps (async), catch step failures, restore the latest valid
checkpoint and continue — the same restart path a preempted pod slice takes.
``FailureInjector`` deterministically raises inside chosen steps so the
recovery path is *tested*, not assumed (tests/test_fault_tolerance.py).

``StragglerMonitor`` keeps an EWMA of step wall-time and flags steps that
exceed ``threshold``x the moving average — the hook where a deployment
triggers its mitigation (re-dispatch, slice swap, data re-balance).  On one
host we log and count; the policy hook is injectable.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax

from repro.training.checkpoint import CheckpointManager


class FailureInjector:
    """Raises RuntimeError at the given (0-based) global steps, once each."""

    def __init__(self, fail_at: List[int]):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")


class StragglerMonitor:
    def __init__(self, threshold: float = 3.0, alpha: float = 0.2,
                 action: Optional[Callable[[int, float, float], None]] = None):
        self.threshold = threshold
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.stragglers: List[int] = []
        self.action = action

    def record(self, step: int, dt: float) -> bool:
        is_straggler = (self.ewma is not None
                        and dt > self.threshold * self.ewma)
        if is_straggler:
            self.stragglers.append(step)
            if self.action:
                self.action(step, dt, self.ewma)
        # stragglers don't poison the EWMA
        if self.ewma is None:
            self.ewma = dt
        elif not is_straggler:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


def resilient_loop(train_step: Callable, state, batches, ckpt: CheckpointManager,
                   ckpt_every: int = 10, injector: Optional[FailureInjector] = None,
                   monitor: Optional[StragglerMonitor] = None,
                   max_restarts: int = 10) -> Dict:
    """Run train_step over ``batches`` (a list) with checkpoint/restart.

    Returns {"state": final_state, "metrics": last, "restarts": n,
    "completed": steps_run}.
    """
    restarts = 0
    metrics = None
    step = 0
    n = len(batches)
    ckpt.save(0, state)
    while step < n:
        try:
            if injector is not None:
                injector.maybe_fail(step)
            t0 = time.time()
            state, metrics = train_step(state, batches[step])
            jax.block_until_ready(metrics["loss"])
            if monitor is not None:
                monitor.record(step, time.time() - t0)
            step += 1
            if step % ckpt_every == 0:
                ckpt.save_async(step, state)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            ckpt.wait()
            state, restored_step = ckpt.restore(jax.eval_shape(lambda: state))
            step = restored_step
    ckpt.wait()
    ckpt.save(step, state)
    return {"state": state, "metrics": metrics, "restarts": restarts,
            "completed": step}
