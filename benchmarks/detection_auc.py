"""Figures 1 & 7 (+ Appendix B Figures 14-15): detection performance across
sampling rates — Peregrine (switch-mode FC, record sampling) vs the Kitsune
baseline (packet sampling), all 15 attacks.

Full run:  PYTHONPATH=src python -m benchmarks.detection_auc
Quick run: ... --quick  (3 attacks, smaller traces — used by benchmarks.run)
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import save
from repro.detection.sweep import sweep_attack
from repro.traffic import ATTACKS, synth_trace

FULL_RATES = (1, 64, 256, 1024)
QUICK_RATES = (1, 256)


def run(attacks, rates, n_train, n_eval, mode="switch", seed=0):
    table = {}
    for attack in attacks:
        t0 = time.time()
        data = synth_trace(attack, n_train=n_train,
                           n_benign_eval=n_eval // 2,
                           n_attack=n_eval // 2, seed=seed)
        table[attack] = sweep_attack(data, rates, mode=mode, seed=seed)
        p = {r: round(v["auc"], 3) for r, v in table[attack]["peregrine"].items()}
        k = {r: round(v["auc"], 3) for r, v in table[attack]["kitsune"].items()}
        print(f"{attack:18s} peregrine={p} kitsune={k} "
              f"[{time.time() - t0:.0f}s]")
    return table


def summarize(table, rates):
    """Paper-style headline: counts of attacks with AUC > 0.8 / < 0.5."""
    out = {}
    for system in ("peregrine", "kitsune"):
        eff = sum(1 for a in table
                  if min(table[a][system][r]["auc"] for r in rates
                         if r > 1) > 0.8)
        dead = sum(1 for a in table
                   if min(table[a][system][r]["auc"] for r in rates
                          if r > 1) < 0.5)
        out[system] = {"auc>0.8_all_sampled_rates": eff,
                       "auc<0.5_at_some_sampled_rate": dead,
                       "n_attacks": len(table)}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--mode", default="switch", choices=("switch", "exact"))
    args = ap.parse_args()
    if args.quick:
        attacks = ("syn_dos", "ssdp_flood", "mirai")
        rates = QUICK_RATES
        table = run(attacks, rates, n_train=8000, n_eval=12000,
                    mode=args.mode)
    else:
        attacks = tuple(ATTACKS)
        rates = FULL_RATES
        table = run(attacks, rates, n_train=60000, n_eval=60000,
                    mode=args.mode)
    head = summarize(table, rates)
    print("headline:", head)
    save("detection_auc" + ("_quick" if args.quick else ""),
         {"rates": rates, "mode": args.mode, "table": table,
          "headline": head})


if __name__ == "__main__":
    main()
