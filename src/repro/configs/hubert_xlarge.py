"""hubert-xlarge — [audio] 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.
Encoder-only transformer backbone (same arch as wav2vec2). The conv waveform
frontend is a STUB per the assignment: input_specs() provides precomputed
frame embeddings (B, S, 1280). Loss = frame-level CE over 504 cluster targets
(HuBERT masked-prediction style). [arXiv:2106.07447; unverified]"""
from repro.configs.base import ArchConfig, AUDIO

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family=AUDIO,
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    embed_inputs=False,
    d_in=1280,
    act="gelu",
)
