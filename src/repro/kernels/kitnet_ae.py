"""KitNET autoencoder-ensemble forward (+RMSE) as a fused Pallas kernel.

The MD stage (§3.4): k small autoencoders reconstruct their feature subset;
their RMSEs feed the output AE.  This kernel fuses the whole ensemble layer:
grid (k, batch_blocks); each step runs one AE on one batch tile —
two MXU matmuls + sigmoids + masked RMSE reduction, never materialising the
reconstruction in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ae_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, mask_ref, out_ref):
    x = x_ref[0].astype(jnp.float32)                     # (bB, m)
    mask = mask_ref[0].astype(jnp.float32)               # (1, m)
    xm = x * mask
    h = jax.nn.sigmoid(
        jax.lax.dot_general(xm, w1_ref[0].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + b1_ref[0].astype(jnp.float32))
    y = jax.nn.sigmoid(
        jax.lax.dot_general(h, w2_ref[0].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + b2_ref[0].astype(jnp.float32))
    se = ((y - xm) ** 2) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    out_ref[0] = jnp.sqrt(se.sum(axis=-1, keepdims=True) / denom)


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def kitnet_ensemble(x_sub, w1, b1, w2, b2, mask, *, bb: int = 128,
                    interpret: bool = True):
    """x_sub: (B, k, m) gathered+normalised feature subsets.
    w1 (k,m,h), b1 (k,h), w2 (k,h,m), b2 (k,m), mask (k,m).
    Returns per-AE RMSE (B, k).
    """
    B, k, m = x_sub.shape
    h = w1.shape[-1]
    bb = min(bb, max(B, 8))
    nb = -(-B // bb)
    Bp = nb * bb
    if Bp != B:
        x_sub = jnp.pad(x_sub, ((0, Bp - B), (0, 0), (0, 0)))
    xk = x_sub.transpose(1, 0, 2)                        # (k, Bp, m)

    out = pl.pallas_call(
        _ae_kernel,
        grid=(k, nb),
        in_specs=[
            pl.BlockSpec((1, bb, m), lambda e, b: (e, b, 0)),
            pl.BlockSpec((1, m, h), lambda e, b: (e, 0, 0)),
            pl.BlockSpec((1, 1, h), lambda e, b: (e, 0, 0)),
            pl.BlockSpec((1, h, m), lambda e, b: (e, 0, 0)),
            pl.BlockSpec((1, 1, m), lambda e, b: (e, 0, 0)),
            pl.BlockSpec((1, 1, m), lambda e, b: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bb, 1), lambda e, b: (e, b, 0)),
        out_shape=jax.ShapeDtypeStruct((k, Bp, 1), jnp.float32),
        interpret=interpret,
    )(xk, w1, b1[:, None, :], w2, b2[:, None, :], mask[:, None, :])
    return out[:, :B, 0].T                               # (B, k)
