"""Flow-state tables — the TPU analogue of the switch's register arrays.

Slots are direct-indexed by ``hash(flow_key) % n_slots`` with *no* collision
resolution, exactly like the switch's stateful SRAM arrays (colliding flows
merge — part of the fidelity model, noted in DESIGN.md §1).

Four decay instances per atom (lambda = 10, 1, 1/10, 1/60 — windows 100ms /
1s / 10s / 60s) as in §4.

Multi-tenant serving stores N independent flow tables as ONE stacked pytree
with a leading tenant axis (:class:`StatePool`, DESIGN.md §10): N tenants
cost one device allocation per leaf, tenant slots are allocated/freed/reset
by index, and the tenant-batched fused step (serving/fused.py) gathers and
scatters slots inside one donated jit so tenant states never mix.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

LAMBDAS = (10.0, 1.0, 0.1, 1.0 / 60.0)
N_DECAY = len(LAMBDAS)

# key types
UNI_KEYS = ("src_mac_ip", "src_ip")            # unidirectional stats
BI_KEYS = ("channel", "socket")                # bidirectional stats
N_UNI, N_BI = len(UNI_KEYS), len(BI_KEYS)

UNI_STATS = ("w", "mean", "std")
BI_STATS = ("w", "mean", "std", "magnitude", "radius", "cov", "pcc")
N_FEATURES = N_UNI * N_DECAY * len(UNI_STATS) + N_BI * N_DECAY * len(BI_STATS)

FEATURE_NAMES = tuple(
    f"{k}:{lam}:{s}"
    for k in UNI_KEYS for lam in LAMBDAS for s in UNI_STATS
) + tuple(
    f"{k}:{lam}:{s}"
    for k in BI_KEYS for lam in LAMBDAS for s in BI_STATS
)


def init_state(n_slots: int) -> Dict:
    """Fresh flow tables. Shapes:

    uni tables: (N_UNI, n_slots, N_DECAY) atoms; bi tables carry a direction
    axis (N_BI, n_slots, 2, N_DECAY) plus channel-level SR state.
    """
    z = jnp.zeros
    return {
        "uni": {
            "last_t": z((N_UNI, n_slots, N_DECAY)) - 1.0,
            "w": z((N_UNI, n_slots, N_DECAY)),
            "ls": z((N_UNI, n_slots, N_DECAY)),
            "ss": z((N_UNI, n_slots, N_DECAY)),
            "rr": z((N_UNI, n_slots), jnp.int32),
        },
        "bi": {
            "last_t": z((N_BI, n_slots, 2, N_DECAY)) - 1.0,
            "w": z((N_BI, n_slots, 2, N_DECAY)),
            "ls": z((N_BI, n_slots, 2, N_DECAY)),
            "ss": z((N_BI, n_slots, 2, N_DECAY)),
            "sr": z((N_BI, n_slots, N_DECAY)),
            "sr_last_t": z((N_BI, n_slots, N_DECAY)) - 1.0,
            "res_last": z((N_BI, n_slots, 2, N_DECAY)),
            "rr": z((N_BI, n_slots), jnp.int32),
        },
    }


def state_slots(state: Dict) -> int:
    """Static slot count, derived from table shapes (jit-safe)."""
    return state["uni"]["w"].shape[1]


def init_state_stacked(n_tenants: int, n_slots: int) -> Dict:
    """N fresh flow-table states as ONE stacked pytree (leading tenant
    axis on every leaf) — the single-allocation layout :class:`StatePool`
    manages and the tenant-batched fused step vmaps over."""
    one = init_state(n_slots)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_tenants,) + x.shape)
        # broadcast_to aliases one buffer across tenants; materialise so
        # per-tenant scatter updates (pool.at[tid].set) stay independent
        .copy(), one)


class StatePool:
    """Bounded pool of per-tenant flow-table states, stacked on device.

    The pool owns ``n_tenants`` tenant slots stored as one stacked pytree
    (``init_state_stacked``): each leaf carries a leading tenant axis, so
    the whole pool is a single device allocation per table, not N — and
    the tenant-batched fused serving step (serving/fused.py) can gather
    any subset of tenant states, run them through one donated jit, and
    scatter them back without the states ever mixing.

    Lifecycle: ``alloc()`` claims a free slot (its state is freshly
    reset), ``free(tid)`` releases it, ``reset(tid)`` zeroes a live
    tenant's tables in place (a new capture on the same slot).  The
    stacked pytree handle lives at ``pool.stacked``; callers that pass it
    through a donated step must write the returned handle back (the
    engine does — DESIGN.md §8 donation contract applies unchanged).
    """

    def __init__(self, n_tenants: int, n_slots: int):
        if n_tenants < 1:
            raise ValueError(f"need at least one tenant slot, got {n_tenants}")
        self.n_tenants = int(n_tenants)
        self.n_slots = int(n_slots)
        self.stacked = init_state_stacked(n_tenants, n_slots)
        self._live: List[bool] = [False] * n_tenants
        # one fresh single-tenant state kept as the reset template so
        # reset() never rebuilds it (host->device) per call
        self._fresh = init_state(n_slots)
        # pristine[t] <=> slot t is known to hold a fresh state, letting
        # alloc() skip the full-pool copy a reset costs; anything that
        # writes a slot outside reset() must clear the flag (write() and
        # the engine's dispatch scatter do — mark_dirty)
        self._pristine: List[bool] = [True] * n_tenants

    # ---- slot lifecycle ----
    @property
    def live(self) -> Tuple[int, ...]:
        """Currently allocated tenant ids, ascending."""
        return tuple(t for t, on in enumerate(self._live) if on)

    @property
    def free_slots(self) -> int:
        return self.n_tenants - len(self.live)

    def alloc(self) -> int:
        """Claim the lowest free tenant slot (freshly reset); raises
        ``RuntimeError`` when the pool is exhausted — the caller decides
        whether that means shed, queue, or grow a new pool."""
        for t, on in enumerate(self._live):
            if not on:
                self._live[t] = True
                if not self._pristine[t]:
                    self.reset(t)
                return t
        raise RuntimeError(
            f"StatePool exhausted: all {self.n_tenants} tenant slots live")

    def free(self, tid: int) -> None:
        """Release a tenant slot.  The actual table reset is deferred to
        the next ``alloc`` of the slot (pristine tracking), so detach is
        O(1) — a later alloc still always starts clean."""
        self._check(tid)
        self._live[tid] = False

    def reset(self, tid: int) -> None:
        """Zero tenant ``tid``'s flow tables in place (fresh capture)."""
        if not 0 <= tid < self.n_tenants:
            raise IndexError(f"tenant {tid} out of range 0..{self.n_tenants - 1}")
        self.stacked = jax.tree_util.tree_map(
            lambda p, f: p.at[tid].set(f), self.stacked, self._fresh)
        self._pristine[tid] = True

    def mark_dirty(self, tids) -> None:
        """Record that ``tids``' slots no longer hold fresh state.  Callers
        that scatter into ``pool.stacked`` directly (the engine's donated
        dispatch does) must call this so a freed slot's next alloc knows to
        reset it."""
        for t in tids:
            self._pristine[int(t)] = False

    def _check(self, tid: int) -> None:
        if not 0 <= tid < self.n_tenants:
            raise IndexError(f"tenant {tid} out of range 0..{self.n_tenants - 1}")
        if not self._live[tid]:
            raise KeyError(f"tenant {tid} is not allocated")

    # ---- state access ----
    def read(self, tid: int) -> Dict:
        """A standalone COPY of tenant ``tid``'s state (safe to keep
        across later pool updates/donations)."""
        self._check(tid)
        return jax.tree_util.tree_map(lambda x: jnp.copy(x[tid]), self.stacked)

    def write(self, tid: int, state: Dict) -> None:
        """Install a standalone single-tenant state into slot ``tid``."""
        self._check(tid)
        self.stacked = jax.tree_util.tree_map(
            lambda p, s: p.at[tid].set(s), self.stacked, state)
        self._pristine[tid] = False


# ---------------------------------------------------------------------------
# Flow-key hashing (CRC-like mix, vectorised)
# ---------------------------------------------------------------------------
def _mix(h: jax.Array, v: jax.Array) -> jax.Array:
    h = (h ^ v) * jnp.uint32(0x9E3779B1)
    return h ^ (h >> 15)


def hash_fields(fields, salt: int) -> jax.Array:
    h = jnp.full(fields[0].shape, jnp.uint32(salt ^ 0x811C9DC5))
    for f in fields:
        h = _mix(h, f.astype(jnp.uint32))
    return h


def packet_slots(pkts: Dict[str, jax.Array], n_slots: int) -> Dict[str, jax.Array]:
    """Per-packet slot indices + channel direction bit.

    pkts: {ts, src, dst, sport, dport, proto, length} arrays of shape (n,).
    Channel/socket keys are canonicalised (min/max endpoint) so both
    directions land in the same slot; ``dir`` = 0 if src is the canonical
    low endpoint else 1.  Equal IPs (same-host/loopback socket pairs) break
    the tie on ports, so the two directions of a swapped-port socket still
    share a slot with opposite ``dir`` bits instead of merging.
    """
    src, dst = pkts["src"], pkts["dst"]
    sport, dport = pkts["sport"], pkts["dport"]
    lo_is_src = (src < dst) | ((src == dst) & (sport <= dport))
    ip_lo = jnp.where(lo_is_src, src, dst)
    ip_hi = jnp.where(lo_is_src, dst, src)
    p_lo = jnp.where(lo_is_src, sport, dport)
    p_hi = jnp.where(lo_is_src, dport, sport)
    ns = jnp.uint32(n_slots)
    return {
        "src_mac_ip": (hash_fields((src,), 1) % ns).astype(jnp.int32),
        "src_ip": (hash_fields((src,), 2) % ns).astype(jnp.int32),
        "channel": (hash_fields((ip_lo, ip_hi), 3) % ns).astype(jnp.int32),
        "socket": (hash_fields((ip_lo, ip_hi, p_lo, p_hi, pkts["proto"]), 4)
                   % ns).astype(jnp.int32),
        "dir": (~lo_is_src).astype(jnp.int32),
    }
