"""Peregrine serving plane: the single-stream ``DetectionService`` and the
multi-tenant ``DetectionEngine`` (DESIGN.md §10).

This package must stay importable without the LM model stack: an
import-graph test (tests/test_engine.py) pins its allowed dependencies to
the detection-plane packages (core/data/detection/traffic/distributed).
The seed's LM serving engine lives at ``repro.models.lm_engine``.
"""
from repro.serving.detect_service import DetectionService  # noqa: F401
from repro.serving.engine import DetectionEngine  # noqa: F401
