"""Reproduce Figure 1 in miniature: the baseline detector collapses under
packet sampling while Peregrine's record sampling holds.

  PYTHONPATH=src python examples/sampling_collapse.py
"""
from repro.detection.sweep import sweep_attack
from repro.traffic import synth_trace

data = synth_trace("ssdp_flood", n_train=10000, n_benign_eval=8000,
                   n_attack=8000, seed=0)
res = sweep_attack(data, rates=(1, 64, 256), mode="switch")

print(f"{'rate':>8s} {'Peregrine AUC':>14s} {'Kitsune AUC':>12s}")
for rate in (1, 64, 256):
    p = res["peregrine"][rate]["auc"]
    k = res["kitsune"][rate]["auc"]
    print(f"1:{rate:<6d} {p:14.3f} {k:12.3f}")
print("\nPeregrine samples feature RECORDS (after per-packet FC); the "
      "baseline samples raw packets before FC — Figure 3's distinction.")
