"""Multi-tenant detection engine: single-tenant bit-parity with
``DetectionService.process_stream``, N-tenant state isolation, bounded-queue
backpressure, the state pool lifecycle, and the ``repro.serving``
import-graph pin (serving/engine.py, core/state.py — DESIGN.md §10)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import init_state
from repro.core.state import StatePool
from repro.serving import DetectionEngine, DetectionService
from repro.traffic import synth_trace

N_SLOTS = 512
EPOCH = 32
CHUNK = 96


def _copy(state):
    return jax.tree_util.tree_map(jnp.copy, state)


def _eval_trace(attack: str, seed: int, n: int = 256):
    d = synth_trace(attack, n_train=64, n_benign_eval=n, n_attack=n,
                    seed=seed)
    return {k: v for k, v in d["eval"].items() if k != "label"}


def _states_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


@pytest.fixture(scope="module")
def svc():
    """One fitted scan-backend service shared by every engine test."""
    data = synth_trace("mirai", n_train=768, n_benign_eval=64,
                       n_attack=64, seed=0)
    s = DetectionService(epoch=EPOCH, n_slots=N_SLOTS, mode="exact",
                         backend="scan")
    s.observe_stream(data["train"], chunk=256)
    s.fit(fpr=0.05)
    return s


# ---------------------------------------------------------------------------
# single-tenant bit-parity with process_stream
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("attack", ["mirai", "syn_dos", "os_scan",
                                    "slowloris"])
def test_single_tenant_engine_matches_process_stream(svc, attack):
    """One tenant through the engine — tenant-batched fused step, pool
    gather/scatter, chunk cutting, partial-tail flush and all — must emit
    bit-identical (indices, scores, alarms) to the single-stream service
    on the same trace, and leave bit-identical flow tables."""
    ev = _eval_trace(attack, seed=11)
    st0, c0 = _copy(svc.state), svc.pkt_count
    want = svc.process_stream(ev, chunk=CHUNK)
    state_after = svc.state
    svc.state, svc.pkt_count = _copy(st0), c0

    eng = DetectionEngine.from_service(svc, n_tenants=2, chunk=CHUNK,
                                       queue_depth=4)
    tid = eng.add_tenant()
    eng.seed_tenant(tid, st0, c0)
    got = eng.run({tid: ev})[tid]
    assert len(want[0]) > 0
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    assert _states_equal(state_after, eng.pool.read(tid))
    # restore the module-scoped service for the next parametrization
    svc.state, svc.pkt_count = st0, c0


# ---------------------------------------------------------------------------
# N-tenant isolation
# ---------------------------------------------------------------------------
def test_tenant_isolation_results_and_states(svc):
    """Each tenant's engine output equals that tenant run ALONE (fresh
    tables both times): co-tenancy in the batched step must not leak
    state, records, or epoch accounting across lanes."""
    attacks = ["syn_dos", "ssdp_flood", "goldeneye", "fuzzing"]
    traces = {k: _eval_trace(a, seed=20 + k) for k, a in enumerate(attacks)}

    eng = DetectionEngine.from_service(svc, n_tenants=4, chunk=CHUNK,
                                       queue_depth=4)
    tids = [eng.add_tenant() for _ in range(4)]
    together = eng.run({tid: traces[k] for k, tid in enumerate(tids)})
    end_states = {k: eng.pool.read(tid) for k, tid in enumerate(tids)}

    for k, tid in enumerate(tids):
        solo = DetectionEngine.from_service(svc, n_tenants=1, chunk=CHUNK,
                                            queue_depth=4)
        t = solo.add_tenant()
        alone = solo.run({t: traces[k]})[t]
        for a, b in zip(together[tid], alone):
            np.testing.assert_array_equal(a, b)
        assert _states_equal(end_states[k], solo.pool.read(t))


def test_tenant_epoch_counters_never_mix(svc):
    """Tenants at different stream positions sample records at their OWN
    epoch boundaries: global indices stay per-tenant-continuous even when
    every chunk rides a shared batched call."""
    ev = _eval_trace("mirai", seed=31, n=160)
    eng = DetectionEngine.from_service(svc, n_tenants=2, chunk=64,
                                       queue_depth=8)
    a, b = eng.add_tenant(), eng.add_tenant()
    # tenant b starts mid-epoch (offset 7): boundaries shift accordingly
    eng.seed_tenant(b, init_state(N_SLOTS), pkt_count=7)
    out = eng.run({a: ev, b: ev})
    ia, ib = out[a][0], out[b][0]
    assert len(ia) and len(ib)
    assert all((i + 1) % EPOCH == 0 for i in ia)
    assert all((i + 1) % EPOCH == 0 for i in ib)
    # both streams hit the same ABSOLUTE boundaries, but tenant b's offset
    # means different packets feed each record — scores must diverge
    np.testing.assert_array_equal(ia, ib)
    assert not np.array_equal(out[a][1], out[b][1])


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------
def test_bounded_queue_sheds_and_reports(svc):
    """A full ingress queue sheds (drop-tail) instead of blocking: the
    accepted prefix is processed normally, counters report the drops, and
    the engine drains without deadlock."""
    ev = _eval_trace("mirai", seed=41, n=300)
    n = len(ev["ts"])
    eng = DetectionEngine.from_service(svc, n_tenants=1, chunk=64,
                                       queue_depth=2)
    tid = eng.add_tenant()
    cap = 2 * 64
    accepted = eng.submit(tid, ev)          # one oversized burst, no ticks
    assert accepted == cap
    assert eng.room(tid) == 0
    assert eng.submit(tid, ev) == 0         # full: everything sheds
    eng.step()
    eng.flush()
    idx, scores, alarms = eng.results(tid)
    st = eng.stats()["tenants"][tid]
    assert st["pkts_dropped"] == (n - cap) + n
    assert st["pkts_processed"] == cap
    assert st["pkts_in"] == 2 * n
    # the accepted prefix is exactly the first `cap` packets of the trace
    svc_state, svc_count = _copy(svc.state), svc.pkt_count
    svc.state, svc.pkt_count = init_state(N_SLOTS), 0
    want = svc.process_stream({k: v[:cap] for k, v in ev.items()}, chunk=64)
    svc.state, svc.pkt_count = svc_state, svc_count
    for w, g in zip(want, (idx, scores, alarms)):
        np.testing.assert_array_equal(w, g)


def test_run_driver_respects_backpressure_without_drops(svc):
    """The offline ``run`` driver pauses feeding instead of shedding, so
    a tiny queue still processes the whole trace."""
    ev = _eval_trace("syn_dos", seed=43, n=200)
    eng = DetectionEngine.from_service(svc, n_tenants=1, chunk=64,
                                       queue_depth=1)
    tid = eng.add_tenant()
    eng.run({tid: ev})
    st = eng.stats()["tenants"][tid]
    assert st["pkts_dropped"] == 0
    assert st["pkts_processed"] == len(ev["ts"])


# ---------------------------------------------------------------------------
# state pool lifecycle
# ---------------------------------------------------------------------------
def test_state_pool_alloc_free_reset():
    pool = StatePool(3, 64)
    a, b = pool.alloc(), pool.alloc()
    assert (a, b) == (0, 1) and pool.live == (0, 1) and pool.free_slots == 1
    # slots are independent: dirty one, the other stays fresh
    pool.stacked = jax.tree_util.tree_map(
        lambda x: x.at[a].set(jnp.ones_like(x[a])), pool.stacked)
    assert _states_equal(pool.read(b), init_state(64))
    assert not _states_equal(pool.read(a), init_state(64))
    pool.reset(a)
    assert _states_equal(pool.read(a), init_state(64))
    pool.free(a)
    assert pool.live == (b,)
    with pytest.raises(KeyError):
        pool.read(a)
    assert pool.alloc() == a            # lowest free slot, freshly reset
    c = pool.alloc()
    assert c == 2
    with pytest.raises(RuntimeError):
        pool.alloc()                    # exhausted: bounded pool rejects
    with pytest.raises(IndexError):
        pool.reset(99)


def test_state_pool_read_is_a_copy():
    pool = StatePool(2, 32)
    t = pool.alloc()
    snap = pool.read(t)
    pool.stacked = jax.tree_util.tree_map(
        lambda x: x.at[t].set(jnp.ones_like(x[t])), pool.stacked)
    assert _states_equal(snap, init_state(32))   # unaffected by the write


def test_engine_add_remove_tenants_reuses_slots(svc):
    eng = DetectionEngine.from_service(svc, n_tenants=2, chunk=64,
                                       queue_depth=2)
    a = eng.add_tenant()
    b = eng.add_tenant()
    with pytest.raises(RuntimeError):
        eng.add_tenant()
    eng.run({a: _eval_trace("mirai", seed=51, n=100)})
    eng.remove_tenant(a)
    c = eng.add_tenant()                 # reuses the freed slot, fresh state
    assert c == a
    assert _states_equal(eng.pool.read(c), init_state(N_SLOTS))
    assert eng.results(c)[0].shape == (0,)
    eng.remove_tenant(b)


# ---------------------------------------------------------------------------
# alarm delivery
# ---------------------------------------------------------------------------
def test_alarm_log_written_per_tenant(svc, tmp_path):
    ev = _eval_trace("syn_dos", seed=61)
    with DetectionEngine.from_service(svc, n_tenants=1, chunk=CHUNK,
                                      queue_depth=4,
                                      alarm_dir=str(tmp_path),
                                      alarm_format="csv") as eng:
        tid = eng.add_tenant()
        idx, scores, alarms = eng.run({tid: ev})[tid]
    n_alarms = int(np.asarray(alarms).sum())
    assert n_alarms > 0
    lines = (tmp_path / f"tenant{tid}.csv").read_text().strip().splitlines()
    assert lines[0] == "tenant,record_index,score"
    assert len(lines) == 1 + n_alarms
    got_idx = [int(l.split(",")[1]) for l in lines[1:]]
    np.testing.assert_array_equal(got_idx, idx[alarms])


# ---------------------------------------------------------------------------
# import-graph pin: repro.serving must not drag the LM stack in
# ---------------------------------------------------------------------------
def test_serving_import_graph_stays_detection_only():
    """Importing ``repro.serving`` must not import the LM model stack
    (``repro.models`` / ``repro.configs`` / ``repro.training``) — the
    seed's LM engine lives at ``repro.models.lm_engine`` now.  Runs in a
    fresh interpreter so this test is immune to import order."""
    allowed = ("repro.core", "repro.data", "repro.detection",
               "repro.distributed", "repro.kernels", "repro.serving",
               "repro.traffic")
    code = (
        "import sys, repro.serving\n"
        "mods = sorted(m for m in sys.modules\n"
        "              if m.startswith('repro.') and m.count('.') >= 1)\n"
        "print('\\n'.join(mods))\n")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, check=True)
    bad = [m for m in out.stdout.split()
           if not m.startswith(allowed)]
    assert not bad, f"repro.serving pulled in disallowed modules: {bad}"
