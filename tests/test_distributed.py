"""Distribution tests on an 8-host-device mesh (subprocess: the main test
process must keep seeing 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """A (2,4) mesh train step produces the same loss as single-device."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, json
        from jax.sharding import PartitionSpec as P
        from repro.configs import TrainConfig, get_arch, reduced
        from repro.data import lm_batches
        from repro.models import build_model
        from repro.training import init_train_state, make_train_step
        from repro.distributed.mesh_rules import make_rules
        from repro.distributed.sharding import (use_rules, AxisRules,
                                                named_shardings, set_mesh)
        from repro.distributed.params import param_specs, opt_specs, batch_specs
        from repro.configs.base import ShapeConfig

        cfg = reduced(get_arch("deepseek-7b"), n_kv_heads=4)
        m = build_model(cfg)
        tc = TrainConfig()
        b = next(iter(lm_batches(cfg.vocab, 8, 16, 1, seed=5)))
        batch = {k: jnp.asarray(v) for k, v in b.items()}

        # single device reference
        state = init_train_state(m, tc, jax.random.PRNGKey(0))
        _, met0 = jax.jit(make_train_step(m, tc))(state, batch)
        ref = float(met0["loss"])

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        shp = ShapeConfig("t", 16, 8, "train")
        rules_d = make_rules(cfg, shp, multi_pod=False, model_size=4, dp_size=2)
        rules = AxisRules(rules_d)
        with use_rules(rules_d):
            state = init_train_state(m, tc, jax.random.PRNGKey(0))
            ps = param_specs(state["params"], cfg, rules, 4)
            os_ = opt_specs(state["opt"], ps, cfg, rules,
                            {"data": 2, "model": 4}, True)
            ss = {"params": ps, "opt": os_, "step": P()}
            bs = batch_specs(cfg, shp, rules)
            with set_mesh(mesh):
                step = jax.jit(make_train_step(m, tc),
                               in_shardings=named_shardings(mesh, (ss, bs)),
                               out_shardings=named_shardings(mesh, (ss, None)))
                new_state, met = step(state, batch)
                loss = float(met["loss"])
        print(json.dumps({"ref": ref, "sharded": loss}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    # fp32 reduction order differs across the (2,4) partition; loss ~ O(7)
    assert abs(res["ref"] - res["sharded"]) < 1e-3 * max(1.0, res["ref"]), res


def test_seq_parallel_decode_matches_dense():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, json, math
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.distributed.seq_parallel import make_seq_parallel_decode
        from repro.distributed.sharding import set_mesh
        from repro.models.attention import decode_attention
        from repro.configs import get_arch, reduced

        cfg = reduced(get_arch("deepseek-7b"))
        mesh = jax.make_mesh((8,), ("data",))
        B, H, K, S, D = 2, 4, 2, 64, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, 1, H, D))
        kc = jax.random.normal(ks[1], (B, S, K, D))
        vc = jax.random.normal(ks[2], (B, S, K, D))
        cache_len = jnp.asarray([40, 64])

        want = decode_attention(q, kc, vc, cfg, cache_len, window=0)

        kv_spec = P(None, "data", None, None)
        q_spec = P(None, None, None, None)
        fn = make_seq_parallel_decode(mesh, ("data",), kv_spec, q_spec)
        with set_mesh(mesh):
            kc_s = jax.device_put(kc, NamedSharding(mesh, kv_spec))
            vc_s = jax.device_put(vc, NamedSharding(mesh, kv_spec))
            got = fn(q, kc_s, vc_s, cache_len)
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32) -
                                    want.astype(jnp.float32))))
        print(json.dumps({"err": err}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["err"] < 1e-4, res


def test_elastic_checkpoint_remesh(tmp_path):
    """Save on a (4,2) mesh, restore on (2,2) with 4 devices — values equal."""
    out = _run(f"""
        import jax, jax.numpy as jnp, numpy as np, json
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.training.checkpoint import CheckpointManager

        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                 "b": jnp.ones((8,))}}
        mesh8 = jax.make_mesh((4, 2), ("data", "model"))
        sh8 = {{"w": NamedSharding(mesh8, P("data", "model")),
                "b": NamedSharding(mesh8, P("model"))}}
        tree8 = jax.tree_util.tree_map(jax.device_put, tree, sh8)
        mgr = CheckpointManager({str(tmp_path)!r})
        mgr.save(1, tree8)

        mesh4 = jax.make_mesh((2, 2), ("data", "model"))
        sh4 = {{"w": NamedSharding(mesh4, P("model", "data")),
                "b": NamedSharding(mesh4, P(None))}}
        restored, _ = mgr.restore(jax.eval_shape(lambda: tree), shardings=sh4)
        ok = bool(jnp.all(restored["w"] == tree["w"])) and \
             bool(jnp.all(restored["b"] == tree["b"]))
        print(json.dumps({{"ok": ok,
                           "shard": str(restored["w"].sharding.spec)}}))
    """)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["ok"], res


def test_dryrun_cell_compiles_on_small_mesh():
    """End-to-end lower+compile of a reduced arch on an 8-device mesh using
    the same machinery as the 512-device dry-run."""
    out = _run("""
        import jax, json
        from repro.launch.dryrun import collective_bytes
        hlo_sample = (
          "  %ar = f32[16,128]{1,0} all-reduce(%x), replica_groups={}\\n"
          "  %ag = (bf16[4,8], bf16[4,8]) all-gather(%y, %z), dim=0\\n"
          "  %d = f32[2] all-to-all-done(%s)\\n")
        print(json.dumps(collective_bytes(hlo_sample)))
    """, devices=8)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["all-reduce"] == 16 * 128 * 4
    assert res["all-gather"] == 2 * 4 * 8 * 2
