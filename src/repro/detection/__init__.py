from repro.detection.kitnet import KitNet, train_kitnet, score_kitnet  # noqa: F401
from repro.detection.md_backends import (  # noqa: F401
    available_md_backends, default_md_backend, ensemble_rmse_records,
    register_md_backend, resolve_md_backend, score_records,
    validate_md_options,
)
from repro.detection.metrics import auc, f1_at_fpr  # noqa: F401
from repro.detection.runner import run_peregrine, run_kitsune_baseline  # noqa: F401
