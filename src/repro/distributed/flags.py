"""Runtime flags threaded to model stacks without signature changes."""
from __future__ import annotations

import contextlib
import threading


class _State(threading.local):
    def __init__(self):
        self.scan_unroll = False
        self.moe_dispatch = None   # None -> dense; else (mesh, dp_axes, ep_axis)
        self.remat_override = None


_STATE = _State()


@contextlib.contextmanager
def use_scan_unroll(on: bool = True):
    """Fully unroll layer scans (dry-run fidelity mode: HLO cost analysis
    counts while-loop bodies once, so the roofline pass lowers unrolled)."""
    prev = _STATE.scan_unroll
    _STATE.scan_unroll = on
    try:
        yield
    finally:
        _STATE.scan_unroll = prev


def scan_unroll() -> bool:
    return _STATE.scan_unroll


@contextlib.contextmanager
def use_local_moe_dispatch(mesh, dp_axes, ep_axis="model"):
    """Route MoE FFN through the shard_map local-dispatch path (§Perf):
    token->expert scatter stays shard-local, expert outputs combine with one
    psum over the EP axis instead of full-buffer all-reduce/all-gather."""
    prev = _STATE.moe_dispatch
    _STATE.moe_dispatch = (mesh, tuple(dp_axes) if not isinstance(dp_axes, str)
                           else (dp_axes,), ep_axis)
    try:
        yield
    finally:
        _STATE.moe_dispatch = prev


def moe_dispatch():
    return _STATE.moe_dispatch


@contextlib.contextmanager
def use_remat_override(policy):
    """Override the per-arch TrainConfig remat policy (§Perf variants)."""
    prev = _STATE.remat_override
    _STATE.remat_override = policy
    try:
        yield
    finally:
        _STATE.remat_override = prev


def remat_override():
    return _STATE.remat_override
