"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, exponential gating)
and sLSTM (scalar memory, exponential gating with stabilizer).

mLSTM is computed in a *chunkwise* parallel form (GLA/SSD-style): intra-chunk
quadratic attention-like term + inter-chunk (C, n, m) recurrence — the TPU
adaptation of the paper's "parallel stabilized" formulation.  sLSTM is a true
sequential recurrence (its recurrent matrix R makes it non-associative) and
runs as lax.scan over time; the assignment's xlstm-125m places sLSTM in 2/12
blocks so this does not dominate.

All gating/stabilizer math runs in fp32.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init

Params = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_dims(cfg: ArchConfig) -> Tuple[int, int]:
    d_inner = 2 * cfg.d_model
    return d_inner, d_inner // cfg.n_heads


def mlstm_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    d_inner, dh = mlstm_dims(cfg)
    H = cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], d, 2 * d_inner, dtype),     # [u, z-gate]
        "wq": dense_init(ks[1], d_inner, d_inner, dtype),
        "wk": dense_init(ks[2], d_inner, d_inner, dtype),
        "wv": dense_init(ks[3], d_inner, d_inner, dtype),
        "w_if": dense_init(ks[4], d_inner, 2 * H, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "norm_g": jnp.zeros((d_inner,), dtype),
        "w_down": dense_init(ks[5], d_inner, d, dtype),
    }


def mlstm_init_state(cfg: ArchConfig, batch: int) -> Dict:
    _, dh = mlstm_dims(cfg)
    H = cfg.n_heads
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def _mlstm_gates(p: Params, u: jax.Array, H: int):
    """u: (B, S, d_inner) -> log_i, log_f each (B, S, H), fp32."""
    raw = jnp.einsum("bse,eh->bsh", u.astype(jnp.float32), p["w_if"]) + p["b_if"]
    i_raw, f_raw = jnp.split(raw, 2, axis=-1)
    log_i = i_raw                                   # exponential input gate
    log_f = -jax.nn.softplus(-f_raw)                # log sigmoid(f_raw)
    return log_i, log_f


def _heads(x: jax.Array, H: int) -> jax.Array:
    B, S, E = x.shape
    return x.reshape(B, S, H, E // H).transpose(0, 2, 1, 3)   # (B,H,S,dh)


def mlstm_cell_chunked(q, k, v, log_i, log_f, state, chunk: int):
    """Chunkwise stabilized mLSTM.

    q,k,v: (B,H,S,dh) fp32; log_i/log_f: (B,S,H) fp32.
    Returns h (B,H,S,dh) and final state {C,n,m}.
    """
    B, H, S, dh = q.shape
    nc = S // chunk
    assert nc * chunk == S
    scale = 1.0 / math.sqrt(dh)
    li = jnp.moveaxis(log_i, -1, 1).reshape(B, H, nc, chunk)
    lf = jnp.moveaxis(log_f, -1, 1).reshape(B, H, nc, chunk)
    rc = lambda t: t.reshape(B, H, nc, chunk, dh)
    qc, kc, vc = rc(q), rc(k), rc(v)

    F = jnp.cumsum(lf, axis=-1)                     # inclusive cumsum of log f
    Ftot = F[..., -1]                               # (B,H,nc)

    # intra-chunk log decay matrix: D[i,j] = F_i - F_j + li_j  (j <= i)
    Dm = F[..., :, None] - F[..., None, :] + li[..., None, :]
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    Dm = jnp.where(tri, Dm, -jnp.inf)               # (B,H,nc,Q,Q)
    a_intra = jnp.max(Dm, axis=-1)                  # (B,H,nc,Q)

    def step(carry, xs):
        C, n, m = carry                             # (B,H,dh,dh),(B,H,dh),(B,H)
        qi, ki, vi, Fi, Fti, Di, ai, lii = xs
        qs = qi * scale
        # stabilizer per position: m_i = max(F_i + m_prev, max_j<=i D_ij)
        m_pos = jnp.maximum(Fi + m[..., None], ai)              # (B,H,Q)
        inter_w = jnp.exp(Fi + m[..., None] - m_pos)            # (B,H,Q)
        intra_w = jnp.exp(Di - m_pos[..., None])                # (B,H,Q,Q)
        s = jnp.einsum("bhqd,bhkd->bhqk", qs, ki)
        h_num = (jnp.einsum("bhqk,bhkd->bhqd", s * intra_w, vi)
                 + jnp.einsum("bhqd,bhde->bhqe", qs, C) * inter_w[..., None])
        # normalizer vector: n_i = sum_j<=i exp(D_ij - m_i) k_j + carry part
        n_vec = (jnp.einsum("bhqk,bhkd->bhqd", intra_w, ki)
                 + n[:, :, None, :] * inter_w[..., None])
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhqd,bhqd->bhq", qs, n_vec)),
                            jnp.exp(-m_pos))
        h = h_num / denom[..., None]
        # chunk-end state update
        a_end = jnp.max(Fti[..., None] - Fi + lii, axis=-1)     # (B,H)
        m_end = jnp.maximum(Fti + m, a_end)
        carry_w = jnp.exp(Fti + m - m_end)                      # (B,H)
        in_w = jnp.exp(Fti[..., None] - Fi + lii - m_end[..., None])  # (B,H,Q)
        C_new = (C * carry_w[..., None, None]
                 + jnp.einsum("bhkd,bhke,bhk->bhde", ki, vi, in_w))
        n_new = n * carry_w[..., None] + jnp.einsum("bhkd,bhk->bhd", ki, in_w)
        return (C_new, n_new, m_end), h

    xs = (jnp.moveaxis(qc, 2, 0), jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0),
          jnp.moveaxis(F, 2, 0), jnp.moveaxis(Ftot, 2, 0),
          jnp.moveaxis(Dm, 2, 0), jnp.moveaxis(a_intra, 2, 0),
          jnp.moveaxis(li, 2, 0))
    (C, n, m), hs = jax.lax.scan(step, (state["C"], state["n"], state["m"]), xs)
    h = jnp.moveaxis(hs, 0, 2).reshape(B, H, S, dh)
    return h, {"C": C, "n": n, "m": m}


def mlstm_fwd(p: Params, x: jax.Array, cfg: ArchConfig,
              state: Dict = None) -> Tuple[jax.Array, Dict]:
    """x: (B, S, d) -> (out, state)."""
    B, S, d = x.shape
    H = cfg.n_heads
    d_inner, dh = mlstm_dims(cfg)
    u, z = jnp.split(jnp.einsum("bsd,de->bse", x, p["w_up"]), 2, axis=-1)
    q = _heads(jnp.einsum("bse,ef->bsf", u, p["wq"]), H).astype(jnp.float32)
    k = _heads(jnp.einsum("bse,ef->bsf", u, p["wk"]), H).astype(jnp.float32)
    v = _heads(jnp.einsum("bse,ef->bsf", u, p["wv"]), H).astype(jnp.float32)
    log_i, log_f = _mlstm_gates(p, u, H)
    st = state or mlstm_init_state(cfg, B)
    chunk = min(cfg.ssm_chunk or 128, S)
    # pad S to a chunk multiple: log_i=-inf (no input), log_f=0 (no decay)
    Sp = -(-S // chunk) * chunk
    if Sp != S:
        pq = ((0, 0), (0, 0), (0, Sp - S), (0, 0))
        q, k, v = jnp.pad(q, pq), jnp.pad(k, pq), jnp.pad(v, pq)
        log_i = jnp.pad(log_i, ((0, 0), (0, Sp - S), (0, 0)),
                        constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, Sp - S), (0, 0)))
    h, new_state = mlstm_cell_chunked(q, k, v, log_i, log_f, st, chunk)
    h = h[:, :, :S]
    h = h.transpose(0, 2, 1, 3).reshape(B, S, d_inner)
    h = _rms(h, p["norm_g"], cfg.norm_eps) * jax.nn.silu(z.astype(jnp.float32))
    return jnp.einsum("bse,ed->bsd", h.astype(x.dtype), p["w_down"]), new_state


def mlstm_decode(p: Params, x: jax.Array, cfg: ArchConfig,
                 state: Dict) -> Tuple[jax.Array, Dict]:
    """Single-step recurrent mLSTM. x: (B, 1, d)."""
    B, _, d = x.shape
    H = cfg.n_heads
    d_inner, dh = mlstm_dims(cfg)
    scale = 1.0 / math.sqrt(dh)
    u, z = jnp.split(jnp.einsum("bsd,de->bse", x, p["w_up"]), 2, axis=-1)
    q = _heads(jnp.einsum("bse,ef->bsf", u, p["wq"]), H)[:, :, 0].astype(jnp.float32)
    k = _heads(jnp.einsum("bse,ef->bsf", u, p["wk"]), H)[:, :, 0].astype(jnp.float32)
    v = _heads(jnp.einsum("bse,ef->bsf", u, p["wv"]), H)[:, :, 0].astype(jnp.float32)
    log_i, log_f = _mlstm_gates(p, u, H)
    li, lf = log_i[:, 0], log_f[:, 0]                         # (B,H)
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fw = jnp.exp(lf + m - m_new)[..., None]
    iw = jnp.exp(li - m_new)[..., None]
    C = C * fw[..., None] + jnp.einsum("bhd,bhe->bhde", k, v) * iw[..., None]
    n = n * fw + k * iw
    num = jnp.einsum("bhd,bhde->bhe", q, C) * scale
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)) * scale,
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(B, 1, d_inner)
    h = _rms(h, p["norm_g"], cfg.norm_eps) * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bse,ed->bsd", h.astype(x.dtype), p["w_down"])
    return out, {"C": C, "n": n, "m": m_new}


def _rms(x, gain, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return x32 * jax.lax.rsqrt(var + eps) * (1.0 + gain.astype(jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 4)
    d_ff = int(4 * d * 4 / 3 / 2) * 2
    return {
        "w_gates": dense_init(ks[0], d, 4 * d, jnp.float32),   # i,f,z,o
        "r_gates": (jax.random.normal(ks[1], (H, dh, 4 * dh), jnp.float32)
                    / math.sqrt(dh)),
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "norm_g": jnp.zeros((d,), dtype),
        "w_ff1": dense_init(ks[2], d, 2 * d_ff, dtype),
        "w_ff2": dense_init(ks[3], d_ff, d, dtype),
    }


def slstm_init_state(cfg: ArchConfig, batch: int) -> Dict:
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.full((batch, d), 1e-6, jnp.float32),
            "h": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -1e30, jnp.float32)}


def _slstm_step(p: Params, H: int, carry, wx_t):
    """wx_t: (B, 4d) pre-computed input projection at step t."""
    c, n, h, m = carry
    B, d = c.shape
    dh = d // H
    hh = h.reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", hh, p["r_gates"]).reshape(B, 4 * d)
    raw = wx_t + rec + p["b_gates"]
    i_raw, f_raw, z_raw, o_raw = jnp.split(raw, 4, axis=-1)
    log_i = i_raw
    log_f = -jax.nn.softplus(-f_raw)
    m_new = jnp.maximum(log_f + m, log_i)
    iw = jnp.exp(log_i - m_new)
    fw = jnp.exp(log_f + m - m_new)
    c_new = fw * c + iw * jnp.tanh(z_raw)
    n_new = fw * n + iw
    h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_fwd(p: Params, x: jax.Array, cfg: ArchConfig,
              state: Dict = None) -> Tuple[jax.Array, Dict]:
    """x: (B, S, d). Sequential lax.scan over time."""
    B, S, d = x.shape
    H = cfg.n_heads
    st = state or slstm_init_state(cfg, B)
    wx = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["w_gates"])
    carry = (st["c"], st["n"], st["h"], st["m"])
    carry, hs = jax.lax.scan(
        lambda c, w: _slstm_step(p, H, c, w), carry, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1)                                 # (B,S,d)
    h = _rms(h, p["norm_g"], cfg.norm_eps).astype(x.dtype)
    # gated FFN (pf = 4/3)
    a, b = jnp.split(jnp.einsum("bsd,df->bsf", h, p["w_ff1"]), 2, axis=-1)
    out = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(a) * b, p["w_ff2"])
    new_state = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
    return out, new_state


def slstm_decode(p: Params, x: jax.Array, cfg: ArchConfig,
                 state: Dict) -> Tuple[jax.Array, Dict]:
    return slstm_fwd(p, x, cfg, state)
