"""kimi-k2-1t-a32b — [moe] 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 (+1 shared). Trillion-param MoE
(paper-table). [arXiv:2501.kimi2; unverified]"""
from repro.configs.base import ArchConfig, MOE

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family=MOE,
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=0,
    d_ff_expert=2048,
    n_experts=384,
    top_k=8,
    n_shared_experts=1,
    vocab=163840,
    rope_theta=50000.0,
)
