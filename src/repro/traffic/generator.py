"""Synthetic packet-trace generation.

The container is offline (no Kitsune/CIC-IDS pcaps), so we synthesise traces
whose *statistical shape* matches the published attack descriptions: rates,
fan-out/fan-in, packet-size distributions, direction mixes and temporal
patterns.  The reproduction validates the paper's *relative* claims
(record-sampling robustness vs packet-sampling collapse; approximation
neutrality), not absolute AUC on CIC-IDS — recorded in DESIGN.md §7.

Every generator returns a dict of numpy arrays (ts sorted ascending):
  ts f32 [s] · src u32 · dst u32 · sport u32 · dport u32 · proto u32 ·
  length f32 [bytes] · label u8 (1 = attack packet)
"""
from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

Trace = Dict[str, np.ndarray]

_TCP, _UDP = 6, 17

# address space helpers (plain uint32 host ids)
_LAN = 0x0A000000          # 10.0.0.0/8
_WAN = 0xC0000000


def _merge(traces: List[Trace]) -> Trace:
    out = {k: np.concatenate([t[k] for t in traces]) for k in traces[0]}
    order = np.argsort(out["ts"], kind="stable")
    return {k: v[order] for k, v in out.items()}


def _mk(ts, src, dst, sport, dport, proto, length, label) -> Trace:
    n = len(ts)
    b = lambda v, dt: np.broadcast_to(np.asarray(v, dt), (n,)).copy()
    return {
        "ts": np.asarray(ts, np.float32),
        "src": b(src, np.uint32), "dst": b(dst, np.uint32),
        "sport": b(sport, np.uint32), "dport": b(dport, np.uint32),
        "proto": b(proto, np.uint32),
        "length": np.asarray(length, np.float32),
        "label": b(label, np.uint8),
    }


# ---------------------------------------------------------------------------
# Benign background: web + dns + ntp + smtp flows, heavy-tailed sizes
# ---------------------------------------------------------------------------
def benign_trace(n_packets: int, duration: float, rng: np.random.Generator,
                 n_clients: int = 40, n_servers: int = 12) -> Trace:
    traces = []
    remaining = n_packets
    while remaining > 0:
        kind = rng.choice(["web", "dns", "ntp", "smtp"], p=[0.6, 0.25, 0.05, 0.1])
        client = _LAN + int(rng.integers(1, n_clients + 1))
        server = _WAN + int(rng.integers(1, n_servers + 1))
        t0 = rng.uniform(0, duration)
        if kind == "web":
            m = int(min(remaining, rng.pareto(1.5) * 8 + 4))
            gaps = rng.exponential(0.02, m)
            ts = t0 + np.cumsum(gaps)
            down = rng.random(m) < 0.65          # server->client heavy
            sizes = np.where(down, rng.normal(1200, 220, m), rng.normal(140, 60, m))
            sport = int(rng.integers(32768, 60000))
            tr = _mk(ts, 0, 0, 0, 0, _TCP, np.clip(sizes, 60, 1514), 0)
            tr["src"] = np.where(down, server, client).astype(np.uint32)
            tr["dst"] = np.where(down, client, server).astype(np.uint32)
            dp = 443 if rng.random() < 0.7 else 80
            tr["sport"] = np.where(down, dp, sport).astype(np.uint32)
            tr["dport"] = np.where(down, sport, dp).astype(np.uint32)
        elif kind == "dns":
            m = int(min(remaining, rng.integers(2, 6)))
            ts = t0 + np.cumsum(rng.exponential(0.05, m))
            down = np.arange(m) % 2 == 1
            sizes = np.where(down, rng.normal(220, 80, m), rng.normal(80, 15, m))
            sport = int(rng.integers(32768, 60000))
            tr = _mk(ts, 0, 0, 0, 0, _UDP, np.clip(sizes, 60, 512), 0)
            tr["src"] = np.where(down, server, client).astype(np.uint32)
            tr["dst"] = np.where(down, client, server).astype(np.uint32)
            tr["sport"] = np.where(down, 53, sport).astype(np.uint32)
            tr["dport"] = np.where(down, sport, 53).astype(np.uint32)
        elif kind == "ntp":
            m = int(min(remaining, 2))
            ts = t0 + np.array([0.0, rng.exponential(0.08)])[:m]
            tr = _mk(ts, client, server, 123, 123, _UDP,
                     np.full(m, 90.0), 0)
            if m == 2:
                tr["src"][1], tr["dst"][1] = server, client
        else:  # smtp
            m = int(min(remaining, rng.integers(6, 20)))
            ts = t0 + np.cumsum(rng.exponential(0.04, m))
            down = rng.random(m) < 0.3
            sizes = np.where(down, rng.normal(160, 40, m), rng.normal(700, 300, m))
            sport = int(rng.integers(32768, 60000))
            tr = _mk(ts, 0, 0, 0, 0, _TCP, np.clip(sizes, 60, 1514), 0)
            tr["src"] = np.where(down, server, client).astype(np.uint32)
            tr["dst"] = np.where(down, client, server).astype(np.uint32)
            tr["sport"] = np.where(down, 25, sport).astype(np.uint32)
            tr["dport"] = np.where(down, sport, 25).astype(np.uint32)
        traces.append(tr)
        remaining -= len(tr["ts"])
    out = _merge(traces)
    return {k: v[:n_packets] for k, v in out.items()}


# ---------------------------------------------------------------------------
# Attacks (statistical shapes from the published descriptions)
# ---------------------------------------------------------------------------
def _atk_syn_dos(n, t0, dur, rng):
    """Single-source TCP SYN flood on one server port: tiny pkts, high rate."""
    ts = t0 + np.sort(rng.uniform(0, dur, n))
    return _mk(ts, _WAN + 0xBAD, _WAN + 1, int(rng.integers(1024, 65535)), 80,
               _TCP, rng.normal(60, 4, n).clip(54, 80), 1)


def _atk_ssdp_flood(n, t0, dur, rng):
    """SSDP amplification: many reflectors send large UDP 1900 to victim."""
    ts = t0 + np.sort(rng.uniform(0, dur, n))
    refl = _WAN + 0x100 + rng.integers(0, 80, n).astype(np.uint32)
    tr = _mk(ts, 0, _LAN + 1, 1900, int(rng.integers(1024, 65535)), _UDP,
             rng.normal(1300, 120, n).clip(300, 1514), 1)
    tr["src"] = refl
    return tr


def _atk_os_scan(n, t0, dur, rng):
    """One source probes many hosts/ports with tiny TCP probes."""
    ts = t0 + np.sort(rng.uniform(0, dur, n))
    tr = _mk(ts, _WAN + 0x5CA, 0, 40000, 0, _TCP,
             rng.normal(60, 3, n).clip(54, 74), 1)
    tr["dst"] = (_LAN + rng.integers(1, 60, n)).astype(np.uint32)
    tr["dport"] = rng.integers(1, 1024, n).astype(np.uint32)
    return tr


def _atk_mirai(n, t0, dur, rng):
    """Mirai: many infected LAN hosts telnet-scan (23/2323) + C&C beacons."""
    ts = t0 + np.sort(rng.uniform(0, dur, n))
    bots = _LAN + 0x200 + rng.integers(0, 25, n).astype(np.uint32)
    tr = _mk(ts, 0, 0, 0, 0, _TCP, rng.normal(66, 8, n).clip(54, 120), 1)
    tr["src"] = bots
    tr["dst"] = (_LAN + rng.integers(1, 200, n)).astype(np.uint32)
    tr["sport"] = rng.integers(1024, 65535, n).astype(np.uint32)
    tr["dport"] = np.where(rng.random(n) < 0.9, 23, 2323).astype(np.uint32)
    return tr


def _atk_fuzzing(n, t0, dur, rng):
    """Protocol fuzzing: random sizes/ports to one server."""
    ts = t0 + np.sort(rng.uniform(0, dur, n))
    tr = _mk(ts, _WAN + 0xF22, _WAN + 2, 0, 0, _TCP,
             rng.uniform(60, 1514, n), 1)
    tr["sport"] = rng.integers(1024, 65535, n).astype(np.uint32)
    tr["dport"] = rng.integers(1, 9000, n).astype(np.uint32)
    return tr


def _atk_arp_mitm(n, t0, dur, rng):
    """ARP MitM: victim traffic re-routed through attacker -> duplicated
    channel with shifted sizes/timing."""
    m = n // 2
    ts1 = t0 + np.sort(rng.uniform(0, dur, m))
    lat = rng.exponential(0.003, m)
    att = _LAN + 0x666
    a = _mk(ts1, _LAN + 3, att, 40000, 40001, _TCP,
            rng.normal(800, 350, m).clip(60, 1514), 1)
    b = _mk(ts1 + lat, att, _WAN + 1, 40001, 443, _TCP, a["length"], 1)
    return _merge([a, b])


def _atk_active_wiretap(n, t0, dur, rng):
    """Wiretap bridge adds latency + retransmissions on existing channels."""
    ts = t0 + np.sort(rng.uniform(0, dur, n))
    retrans = rng.random(n) < 0.35
    sizes = np.where(retrans, 1514, rng.normal(900, 300, n)).clip(60, 1514)
    tr = _mk(ts, _LAN + 5, _WAN + 1, 45000, 443, _TCP, sizes, 1)
    down = rng.random(n) < 0.5
    tr["src"] = np.where(down, _WAN + 1, _LAN + 5).astype(np.uint32)
    tr["dst"] = np.where(down, _LAN + 5, _WAN + 1).astype(np.uint32)
    tr["sport"] = np.where(down, 443, 45000).astype(np.uint32)
    tr["dport"] = np.where(down, 45000, 443).astype(np.uint32)
    return tr


def _atk_ssl_renegotiation(n, t0, dur, rng):
    """THC-SSL-DoS: repeated renegotiation handshakes on 443."""
    ts = t0 + np.sort(rng.uniform(0, dur, n))
    tr = _mk(ts, _WAN + 0x55D, _WAN + 1, 0, 443, _TCP,
             rng.normal(150, 60, n).clip(60, 600), 1)
    tr["sport"] = (40000 + (np.arange(n) % 64)).astype(np.uint32)
    return tr


def _atk_video_injection(n, t0, dur, rng):
    """Injected RTP video stream: constant large UDP bursts into a channel."""
    bursts = max(1, n // 12)
    ts = []
    for i in range(bursts):
        base = t0 + i * dur / bursts
        ts.append(base + np.cumsum(rng.exponential(0.0008, 12)))
    ts = np.sort(np.concatenate(ts)[:n])
    return _mk(ts, _LAN + 0x777, _LAN + 8, 5004, 5004, _UDP,
               rng.normal(1400, 60, n).clip(800, 1514), 1)


def _atk_ssh_bruteforce(n, t0, dur, rng):
    """Repeated short SSH sessions: bursts of small pkts on 22."""
    sess = max(1, n // 14)
    traces = []
    for i in range(sess):
        base = t0 + i * dur / sess + rng.exponential(0.1)
        m = 14
        ts = base + np.cumsum(rng.exponential(0.01, m))
        down = np.arange(m) % 2 == 1
        sizes = np.where(down, rng.normal(120, 30, m), rng.normal(90, 20, m))
        tr = _mk(ts, 0, 0, 0, 0, _TCP, sizes.clip(60, 300), 1)
        att, srv = _WAN + 0xB4F, _LAN + 2
        sport = 30000 + i % 2000
        tr["src"] = np.where(down, srv, att).astype(np.uint32)
        tr["dst"] = np.where(down, att, srv).astype(np.uint32)
        tr["sport"] = np.where(down, 22, sport).astype(np.uint32)
        tr["dport"] = np.where(down, sport, 22).astype(np.uint32)
        traces.append(tr)
    out = _merge(traces)
    return {k: v[:n] for k, v in out.items()}


def _atk_ftp_bruteforce(n, t0, dur, rng):
    tr = _atk_ssh_bruteforce(n, t0, dur, rng)
    tr["sport"] = np.where(tr["sport"] == 22, 21, tr["sport"]).astype(np.uint32)
    tr["dport"] = np.where(tr["dport"] == 22, 21, tr["dport"]).astype(np.uint32)
    return tr


def _atk_ddos_hulk(n, t0, dur, rng):
    """HULK: many sources, randomized HTTP GET floods on one server."""
    ts = t0 + np.sort(rng.uniform(0, dur, n))
    tr = _mk(ts, 0, _WAN + 1, 0, 80, _TCP, rng.normal(350, 120, n).clip(60, 800), 1)
    tr["src"] = (_WAN + 0x2000 + rng.integers(0, 300, n)).astype(np.uint32)
    tr["sport"] = rng.integers(1024, 65535, n).astype(np.uint32)
    return tr


def _atk_ddos_loic(n, t0, dur, rng):
    """LOIC UDP flood: medium constant-size packets from many sources."""
    ts = t0 + np.sort(rng.uniform(0, dur, n))
    tr = _mk(ts, 0, _WAN + 1, 0, 80, _UDP, rng.normal(500, 30, n).clip(200, 700), 1)
    tr["src"] = (_WAN + 0x3000 + rng.integers(0, 150, n)).astype(np.uint32)
    tr["sport"] = rng.integers(1024, 65535, n).astype(np.uint32)
    return tr


def _atk_goldeneye(n, t0, dur, rng):
    """GoldenEye: keep-alive HTTP floods, fewer sources, persistent sockets."""
    ts = t0 + np.sort(rng.uniform(0, dur, n))
    tr = _mk(ts, 0, _WAN + 1, 0, 80, _TCP, rng.normal(420, 90, n).clip(100, 900), 1)
    tr["src"] = (_WAN + 0x4000 + rng.integers(0, 12, n)).astype(np.uint32)
    tr["sport"] = (20000 + rng.integers(0, 40, n)).astype(np.uint32)
    return tr


def _atk_slowloris(n, t0, dur, rng):
    """Slowloris: many sockets, tiny pkts, very slow inter-arrival."""
    socks = 150
    per = max(1, n // socks)
    traces = []
    for i in range(socks):
        ts = t0 + np.sort(rng.uniform(0, dur, per))
        tr = _mk(ts, _WAN + 0x510, _WAN + 1, 25000 + i, 80, _TCP,
                 rng.normal(70, 8, per).clip(54, 120), 1)
        traces.append(tr)
    out = _merge(traces)
    return {k: v[:n] for k, v in out.items()}


def _atk_infiltration(n, t0, dur, rng):
    """Infiltration: internal pivot — LAN host starts scanning + exfil."""
    half = n // 2
    scan = _atk_os_scan(half, t0, dur, rng)
    scan["src"][:] = _LAN + 7
    ts = t0 + np.sort(rng.uniform(0, dur, n - half))
    exfil = _mk(ts, _LAN + 7, _WAN + 0xEE, 40000, 443, _TCP,
                rng.normal(1350, 120, n - half).clip(600, 1514), 1)
    return _merge([scan, exfil])


ATTACKS: Dict[str, Callable] = {
    "mirai": _atk_mirai,
    "syn_dos": _atk_syn_dos,
    "ssdp_flood": _atk_ssdp_flood,
    "os_scan": _atk_os_scan,
    "fuzzing": _atk_fuzzing,
    "arp_mitm": _atk_arp_mitm,
    "active_wiretap": _atk_active_wiretap,
    "ssl_renegotiation": _atk_ssl_renegotiation,
    "video_injection": _atk_video_injection,
    "ssh_bruteforce": _atk_ssh_bruteforce,
    "ftp_bruteforce": _atk_ftp_bruteforce,
    "ddos_hulk": _atk_ddos_hulk,
    "ddos_loic": _atk_ddos_loic,
    "goldeneye": _atk_goldeneye,
    "slowloris": _atk_slowloris,
}


def attack_trace(name: str, n: int, t0: float, dur: float, seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    return ATTACKS[name](n, t0, dur, rng)


def synth_trace(attack: str, n_train: int = 20000, n_benign_eval: int = 20000,
                n_attack: int = 20000, seed: int = 0,
                rate_pps: float = 2000.0) -> Dict[str, Trace]:
    """Paper-style trace: benign prefix (training), then eval window with
    benign + attack interleaved. Returns {"train": ..., "eval": ...}."""
    rng = np.random.default_rng(seed)
    dur_train = n_train / rate_pps
    dur_eval = (n_benign_eval + n_attack) / rate_pps
    train = benign_trace(n_train, dur_train, rng)
    benign_ev = benign_trace(n_benign_eval, dur_eval, rng)
    benign_ev["ts"] += dur_train
    atk = attack_trace(attack, n_attack, dur_train + 0.1 * dur_eval,
                       0.8 * dur_eval, seed + 1)
    ev = _merge([benign_ev, atk])
    return {"train": train, "eval": ev}


def to_jnp(trace: Trace):
    import jax.numpy as jnp
    return {k: jnp.asarray(v) for k, v in trace.items() if k != "label"}
