"""Peregrine core: serial oracle vs parallel segment-scan, state chaining,
switch-mode semantics, record sampling."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (init_state, process_serial, process_parallel,
                        N_FEATURES, FEATURE_NAMES, epoch_indices)
from repro.core.records import (epoch_sample, per_flow_epoch_indices,
                                reservoir_indices)

RNG = np.random.default_rng(7)


def _pkts(n, n_hosts=6, t_max=8.0, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "ts": jnp.asarray(np.sort(rng.uniform(0, t_max, n)).astype(np.float32)),
        "src": jnp.asarray(rng.integers(0, n_hosts, n), jnp.uint32),
        "dst": jnp.asarray(rng.integers(0, n_hosts, n), jnp.uint32),
        "sport": jnp.asarray(rng.integers(1000, 1006, n), jnp.uint32),
        "dport": jnp.asarray(rng.integers(80, 83, n), jnp.uint32),
        "proto": jnp.asarray(np.full(n, 6), jnp.uint32),
        "length": jnp.asarray(rng.integers(60, 1500, n), jnp.float32),
    }


def test_feature_count():
    st = init_state(256)
    _, feats = process_serial(st, _pkts(50), mode="exact")
    assert feats.shape == (50, N_FEATURES) == (50, 80)
    assert len(FEATURE_NAMES) == N_FEATURES


def test_parallel_matches_serial_exact():
    pkts = _pkts(400)
    st = init_state(512)
    st_s, f_s = process_serial(st, pkts, mode="exact")
    st_p, f_p = process_parallel(st, pkts)
    np.testing.assert_allclose(np.asarray(f_p), np.asarray(f_s),
                               rtol=1e-3, atol=1.0)
    for grp in ("uni", "bi"):
        for k in st_s[grp]:
            if k == "rr":
                continue
            np.testing.assert_allclose(np.asarray(st_p[grp][k]),
                                       np.asarray(st_s[grp][k]),
                                       rtol=1e-3, atol=1.0, err_msg=f"{grp}/{k}")


def test_parallel_chained_batches_match_one_shot():
    """Processing a trace in chunks must equal one-shot (state stitching).

    Tolerance is statistical: pcc = cov/(sigma_i*sigma_j) has near-zero
    denominators whose fp32 scan-order rounding can amplify arbitrarily, so
    we require >=99.9% of feature cells within (atol=1, rtol=1e-3) and ALL
    non-pcc cells within tolerance.
    """
    pkts = _pkts(300, seed=3)
    st = init_state(256)
    _, f_once = process_parallel(st, pkts)
    st_c = init_state(256)
    outs = []
    for i in range(0, 300, 100):
        chunk = {k: v[i:i + 100] for k, v in pkts.items()}
        st_c, f = process_parallel(st_c, chunk)
        outs.append(np.asarray(f))
    fa, fo = np.concatenate(outs), np.asarray(f_once)
    ok = np.abs(fa - fo) <= (1.0 + 1e-3 * np.abs(fo))
    assert ok.mean() >= 0.999, ok.mean()
    pcc_cols = [i for i, n in enumerate(FEATURE_NAMES) if n.endswith(":pcc")]
    non_pcc = np.setdiff1d(np.arange(fo.shape[1]), pcc_cols)
    assert ok[:, non_pcc].all()


def test_switch_mode_finite_and_integer_stats():
    pkts = _pkts(200, seed=5)
    st = init_state(256)
    _, feats = process_serial(st, pkts, mode="switch")
    f = np.asarray(feats)
    assert np.isfinite(f).all()
    # switch arithmetic is integer-valued for mean/std (floored shifts)
    names = list(FEATURE_NAMES)
    mean_cols = [i for i, n in enumerate(names) if n.endswith(":mean")]
    assert np.allclose(f[:, mean_cols], np.round(f[:, mean_cols]))


def test_weight_feature_counts_packets():
    """For a single flow with sub-window gaps, w == packet index + 1."""
    n = 20
    pkts = {
        "ts": jnp.asarray(np.arange(n) * 0.001, jnp.float32),  # << 100ms
        "src": jnp.full((n,), 1, jnp.uint32),
        "dst": jnp.full((n,), 2, jnp.uint32),
        "sport": jnp.full((n,), 1000, jnp.uint32),
        "dport": jnp.full((n,), 80, jnp.uint32),
        "proto": jnp.full((n,), 6, jnp.uint32),
        "length": jnp.full((n,), 100.0, jnp.float32),
    }
    st = init_state(128)
    _, feats = process_serial(st, pkts, mode="exact")
    w = np.asarray(feats[:, 0])     # src_mac_ip, lambda=10, w
    # exact decay applies continuously: w_i = sum_k delta^k, delta=2^(-10*1ms)
    delta = 2.0 ** (-10 * 0.001)
    want = (1 - delta ** np.arange(1, n + 1)) / (1 - delta)
    np.testing.assert_allclose(w, want, rtol=1e-4)
    # constant packet size -> std ~ 0, mean == 100
    mu = np.asarray(feats[:, 1])
    sd = np.asarray(feats[:, 2])
    np.testing.assert_allclose(mu, 100.0, rtol=1e-4)
    assert np.abs(sd).max() < 0.1


def test_decay_reduces_weight():
    """A long gap (>> window) decays w towards zero before the next hit."""
    pkts = {
        "ts": jnp.asarray([0.0, 0.001, 0.002, 100.0], jnp.float32),
        "src": jnp.full((4,), 1, jnp.uint32),
        "dst": jnp.full((4,), 2, jnp.uint32),
        "sport": jnp.full((4,), 1000, jnp.uint32),
        "dport": jnp.full((4,), 80, jnp.uint32),
        "proto": jnp.full((4,), 6, jnp.uint32),
        "length": jnp.full((4,), 100.0, jnp.float32),
    }
    st = init_state(128)
    _, feats = process_serial(st, pkts, mode="exact")
    w_fast = np.asarray(feats[:, 0])     # lambda=10 decay
    assert w_fast[2] > 2.9               # three rapid packets
    assert w_fast[3] < 1.1               # decayed across 100s gap


def test_epoch_sampling():
    idx = epoch_indices(100, 10)
    assert list(idx) == [9, 19, 29, 39, 49, 59, 69, 79, 89, 99]
    idx2 = epoch_indices(100, 10, offset=5)
    assert list(idx2)[0] == 4
    feats = jnp.arange(50 * 3, dtype=jnp.float32).reshape(50, 3)
    recs, ids = epoch_sample(feats, 25)
    assert recs.shape == (2, 3)


def test_per_flow_and_reservoir_samplers():
    slots = np.array([0, 0, 1, 0, 1, 1, 2, 0])
    idx = per_flow_epoch_indices(slots, 2)
    # every 2nd packet of each flow: positions 1 (flow0 #2), 4 (flow1 #2),
    # 7 (flow0 #4) — and nothing else (flow2 has a single packet)
    assert list(idx) == [1, 4, 7]
    r = reservoir_indices(100, 10, seed=1)
    assert len(r) == 10 and (np.diff(r) > 0).all()


def test_per_flow_rank_is_per_flow_not_global():
    """Regression: first_pos initialised to zeros made the per-flow rank
    degenerate to the global packet index, so the sampler picked plain
    epoch positions.  Interleaved flows expose the difference."""
    slots = np.array([0, 1, 0, 1, 0, 1])
    # flow0 at 0,2,4 and flow1 at 1,3,5 -> 2nd packet of each: 2 and 3.
    # (The degenerate version returned the odd global positions [1, 3, 5].)
    assert list(per_flow_epoch_indices(slots, 2)) == [2, 3]
    # multi-flow trace: every flow contributes exactly floor(count/epoch)
    rng = np.random.default_rng(0)
    slots = rng.integers(0, 7, 200)
    idx = per_flow_epoch_indices(slots, 3)
    want = sum(np.sum(slots == s) // 3 for s in np.unique(slots))
    assert len(idx) == want
    # each flow's picked packets are its 3rd, 6th, ... occurrences
    for s in np.unique(slots):
        pos = np.flatnonzero(slots == s)
        assert set(idx) & set(pos) == set(pos[2::3])
    assert len(per_flow_epoch_indices(np.array([], dtype=int), 4)) == 0


def test_same_ip_socket_directions_share_slot():
    """Regression: ``src <= dst`` gave both directions of a same-IP socket
    pair dir=0 and hashed them to different slots (ports not canonical)."""
    from repro.core import packet_slots
    pk = {
        "src": jnp.asarray([7, 7], jnp.uint32),
        "dst": jnp.asarray([7, 7], jnp.uint32),
        "sport": jnp.asarray([1000, 2000], jnp.uint32),
        "dport": jnp.asarray([2000, 1000], jnp.uint32),
        "proto": jnp.asarray([6, 6], jnp.uint32),
    }
    sl = packet_slots(pk, 512)
    assert int(sl["socket"][0]) == int(sl["socket"][1])
    assert int(sl["channel"][0]) == int(sl["channel"][1])
    assert int(sl["dir"][0]) == 0 and int(sl["dir"][1]) == 1
    # distinct IPs keep the IP-ordered canonicalisation
    pk2 = {**pk, "src": jnp.asarray([3, 9], jnp.uint32),
           "dst": jnp.asarray([9, 3], jnp.uint32)}
    sl2 = packet_slots(pk2, 512)
    assert int(sl2["socket"][0]) == int(sl2["socket"][1])
    assert int(sl2["dir"][0]) == 0 and int(sl2["dir"][1]) == 1
