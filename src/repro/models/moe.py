"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Design notes (TPU/SPMD):
  * Dispatch is *static-shape*: tokens are routed into an (E, C, d) buffer via
    scatter, experts run as one batched einsum (grouped GEMM on the MXU), and
    results gather back.  No (N, E, C) one-hot tensor is ever built, so the
    pattern scales to kimi-k2 (384 experts, 1M tokens/step).
  * Under the production mesh the expert axis is sharded over "model" (EP) and
    the capacity axis over ("pod","data"); GSPMD lowers the scatter/gather to
    all-to-alls — the collective the roofline analysis attributes to EP.
  * Router runs in fp32; aux load-balancing loss follows Switch-Transformer.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.distributed import flags
from repro.distributed.sharding import current_rules, lshard
from repro.models.layers import act_fn, dense_init, mlp_fwd, mlp_init

Params = Dict[str, jax.Array]


def moe_init(key, cfg: ArchConfig, dtype) -> Params:
    d, f, E = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    kr, ki, kg, ko, ks = jax.random.split(key, 5)
    scale = 1.0 / (d ** 0.5)
    p = {
        "router": dense_init(kr, d, E, jnp.float32),
        "wi": (jax.random.normal(ki, (E, d, f), jnp.float32) * scale).astype(dtype),
        "wg": (jax.random.normal(kg, (E, d, f), jnp.float32) * scale).astype(dtype),
        "wo": (jax.random.normal(ko, (E, f, d), jnp.float32) * (1.0 / f ** 0.5)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks, d, cfg.d_ff_expert * cfg.n_shared_experts, dtype)
    return p


def capacity(cfg: ArchConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly layout


def moe_ffn(p: Params, x: jax.Array, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Two dispatch strategies:
      * dense (default, paper-naive): global-view scatter/gather into the
        (E, C, d) buffer — GSPMD lowers the cross-shard scatter to
        full-buffer all-reduce/all-gather per layer (measured in §Perf).
      * local (``flags.use_local_moe_dispatch``): shard_map keeps the scatter
        shard-local; each EP shard computes only its experts and token
        outputs merge with ONE psum over the EP axis (see moe_ffn_local).
    """
    if flags.moe_dispatch() is not None:
        return moe_ffn_local(p, x, cfg)
    B, S, d = x.shape
    N = B * S
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(cfg, N)
    xt = x.reshape(N, d)

    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # (N, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Switch-style aux loss: E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (N * k)
    aux = E * jnp.sum(me * ce)

    # ---- dispatch: sort token-slots by expert, position = rank in expert ----
    flat_e = expert_idx.reshape(-1)                            # (N*k,)
    flat_t = jnp.repeat(jnp.arange(N), k)                      # (N*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)                    # (E,)
    starts = jnp.cumsum(counts) - counts                       # exclusive
    pos_sorted = jnp.arange(N * k) - starts[sorted_e]          # rank within expert
    pos = jnp.zeros((N * k,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < C                                             # capacity drop

    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[flat_e, jnp.where(keep, pos, C)].set(
        xt[flat_t], mode="drop")                               # pos==C drops
    buf = lshard(buf, "experts", "expert_cap", None)

    # ---- expert computation: batched einsum over E ----
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    h = act_fn(cfg.act)(g) * h
    # NB: "ff" must NOT be added here — EP already consumes the model axis
    h = lshard(h, "experts", "expert_cap", None)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    out_buf = lshard(out_buf, "experts", "expert_cap", None)

    # ---- combine ----
    gathered = out_buf[flat_e, jnp.minimum(pos, C - 1)]        # (N*k, d)
    w = (gate_vals.reshape(-1) * keep).astype(x.dtype)
    y = jnp.sum((gathered * w[:, None]).reshape(N, k, d), axis=1)

    if cfg.n_shared_experts:
        y = y + mlp_fwd(p["shared"], x, cfg.act).reshape(N, d)
    return y.reshape(B, S, d), aux


# ===========================================================================
# Local (shard_map) dispatch — §Perf optimization
# ===========================================================================
def _routing(xt, router, cfg):
    """Shared routing math. xt: (n, d) -> (gates (n,k), idx (n,k), aux)."""
    n = xt.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("nd,de->ne", xt.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(1.0) / (n * k)
    aux = E * jnp.sum(me * ce)
    return gate_vals, expert_idx, aux


def _dispatch_positions(expert_idx, n, k, E, C):
    """Rank-in-expert positions (shared by both dispatch modes)."""
    flat_e = expert_idx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(n * k) - starts[sorted_e]
    pos = jnp.zeros((n * k,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))
    keep = pos < C
    return flat_e, flat_t, pos, keep


def moe_ffn_local(p: Params, x: jax.Array, cfg: ArchConfig
                  ) -> Tuple[jax.Array, jax.Array]:
    """shard_map MoE: local scatter, EP-sliced expert compute, one psum.

    Collective budget per layer (vs dense dispatch, kimi-k2 train_4k cell):
      dense: O(E*C*d) all-reduce + all-gather  (~150 GB/layer global)
      local: one psum of the token activations (N_loc * d per device)
             + the explicit FSDP weight gather (shared by both modes)
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh, dp_axes, ep_axis = flags.moe_dispatch()
    B, S, d = x.shape
    N = B * S
    E, k = cfg.n_experts, cfg.top_k
    ep = int(mesh.shape[ep_axis])
    dp = int(np.prod([mesh.shape[a] for a in dp_axes]))
    assert E % ep == 0 and N % dp == 0, (E, ep, N, dp)
    E_loc = E // ep
    N_loc = N // dp
    C_loc = capacity(cfg, N_loc)
    f = cfg.d_ff_expert

    rules = current_rules()
    fsdp_axes = rules.rules.get("fsdp") if rules else None
    fsdp_sharded = (fsdp_axes is not None
                    and d % dp == 0 and p["wi"].ndim == 3)

    xt = x.reshape(N, d)
    dspec = tuple(dp_axes) if len(dp_axes) > 1 else dp_axes[0]
    w_spec = P(ep_axis, dspec, None) if fsdp_sharded else P(ep_axis, None, None)

    def local(xt_loc, router, wi, wg, wo):
        # xt_loc: (N_loc, d); wi/wg: (E_loc, d[/dp], f); wo: (E_loc, f[/dp], d)
        if fsdp_sharded:   # explicit FSDP gather — once per layer per matrix
            wi = jax.lax.all_gather(wi, dp_axes, axis=1, tiled=True)
            wg = jax.lax.all_gather(wg, dp_axes, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, dp_axes, axis=1, tiled=True)
        gates, idx, aux = _routing(xt_loc, router, cfg)
        flat_e, flat_t, pos, keep = _dispatch_positions(idx, N_loc, k, E, C_loc)

        # scatter straight into THIS shard's (E_loc, C_loc, d) slab — no
        # replicated (E, C, d) buffer, so the backward cotangent stays local
        # (a replicated buf + slice cost 968 GiB of bwd all-reduce; §Perf A2)
        ep_idx = jax.lax.axis_index(ep_axis)
        local_e = flat_e - ep_idx * E_loc
        mine = (local_e >= 0) & (local_e < E_loc) & keep
        buf_ep = jnp.zeros((E_loc, C_loc, d), x.dtype)
        buf_ep = buf_ep.at[jnp.where(mine, local_e, E_loc),
                           jnp.where(mine, pos, C_loc)].set(
            xt_loc[flat_t], mode="drop")                 # OOB rows drop

        h = jnp.einsum("ecd,edf->ecf", buf_ep, wi)
        g = jnp.einsum("ecd,edf->ecf", buf_ep, wg)
        out_ep = jnp.einsum("ecf,efd->ecd", act_fn(cfg.act)(g) * h, wo)

        # combine: gather this shard's expert outputs back to token slots
        vals = out_ep[jnp.clip(local_e, 0, E_loc - 1),
                      jnp.minimum(pos, C_loc - 1)]       # (N_loc*k, d)
        w = (gates.reshape(-1) * mine).astype(x.dtype)
        y_loc = jnp.sum((vals * w[:, None]).reshape(N_loc, k, d), axis=1)
        y_loc = jax.lax.psum(y_loc, ep_axis)             # THE one collective
        aux = jax.lax.pmean(aux, dp_axes)
        return y_loc, aux

    y, aux = shard_map(
        local, mesh=mesh,
        in_specs=(P(dspec, None), P(), w_spec, w_spec, w_spec),
        out_specs=(P(dspec, None), P()),
        check_rep=False,
    )(xt, p["router"], p["wi"], p["wg"], p["wo"])

    if cfg.n_shared_experts:
        y = y + mlp_fwd(p["shared"], x, cfg.act).reshape(N, d)
    return y.reshape(B, S, d), aux
