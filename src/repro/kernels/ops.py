"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only: the
kernels execute their bodies in Python-on-CPU for validation.  On a real TPU
deployment set ``REPRO_PALLAS_COMPILE=1`` (or pass ``interpret=False``).
The environment variable is read at *call* time, so flipping it takes
effect without re-importing this module; an explicit ``interpret=`` always
wins over the environment.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.feature_update import (
    feature_update as _feat,
    feature_update_full as _feat_full,
)
from repro.kernels.kitnet_ae import kitnet_ensemble as _kitnet
from repro.kernels.sketch_update import sketch_update_full as _sketch_full


def interpret_default() -> bool:
    """Current interpret/compile choice from ``REPRO_PALLAS_COMPILE``."""
    return os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


def _resolve(interpret) -> bool:
    return interpret_default() if interpret is None else interpret


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    bq=128, bk=128, interpret=None):
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  bq=bq, bk=bk, interpret=_resolve(interpret))


def feature_update(table, slots, ts, lens, *, chunk=256, interpret=None):
    return _feat(table, slots.astype(jnp.int32), ts.astype(jnp.float32),
                 lens.astype(jnp.float32), chunk=chunk,
                 interpret=_resolve(interpret))


def feature_update_full(state, pkts, *, chunk=256, interpret=None):
    """Full 80-feature Peregrine FC (all key types + bi stats) in Pallas."""
    return _feat_full(state, pkts, chunk=chunk, interpret=_resolve(interpret))


def sketch_update_full(state, pkts, *, chunk=256, interpret=None):
    """Count-Min sketch FC (all 80 features, CU + eviction) in Pallas."""
    return _sketch_full(state, pkts, chunk=chunk,
                        interpret=_resolve(interpret))


def kitnet_ensemble(x_sub, w1, b1, w2, b2, mask, *, bb=128, interpret=None):
    return _kitnet(x_sub, w1, b1, w2, b2, mask, bb=bb,
                   interpret=_resolve(interpret))
