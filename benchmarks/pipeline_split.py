"""Figures 9-10: relative weight of Feature Computation vs ML Detection in
the end-to-end pipeline, per attack — the justification for offloading FC.

The paper finds FC > 50% of processing time for most attacks; offloading it
to the switch then ~doubles detector throughput (Fig. 9).  We measure both
stages on identical record streams and report the split + implied speedup.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import save, timeit
from repro.core import compute_features, init_state
from repro.detection.kitnet import score_kitnet, train_kitnet
from repro.traffic import ATTACKS, synth_trace, to_jnp


def split_for(attack: str, n: int, seed: int = 0, backend: str = "scan"):
    data = synth_trace(attack, n_train=n, n_benign_eval=n // 2,
                       n_attack=n // 2, seed=seed)
    st = init_state(8192)
    pk_tr = to_jnp(data["train"])
    pk_ev = to_jnp(data["eval"])
    st, f_tr = compute_features(st, pk_tr, backend=backend)
    net = train_kitnet(np.asarray(f_tr)[:2000], seed=seed)

    t_fc = timeit(lambda: jax.block_until_ready(
        compute_features(st, pk_ev, backend=backend)[1]), reps=3)
    _, f_ev = compute_features(st, pk_ev, backend=backend)
    f_ev = np.asarray(f_ev)
    t_md = timeit(lambda: score_kitnet(net, f_ev), reps=3)
    fc_share = t_fc / (t_fc + t_md)
    # Fig 9: offloading FC leaves only MD on the server -> speedup:
    speedup = (t_fc + t_md) / t_md
    return {"fc_s": t_fc, "md_s": t_md, "fc_share": fc_share,
            "offload_speedup": speedup}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default="scan",
                    help="FC backend name "
                         "(serial/scan/bucketed/pallas/sharded)")
    args = ap.parse_args()
    attacks = ("syn_dos", "mirai", "ssdp_flood") if args.quick else tuple(ATTACKS)
    n = 6000 if args.quick else 20000
    out = {}
    for a in attacks:
        out[a] = split_for(a, n, backend=args.backend)
        print(f"{a:18s} FC={out[a]['fc_share'] * 100:5.1f}%  "
              f"offload speedup={out[a]['offload_speedup']:.2f}x")
    share = np.mean([v["fc_share"] for v in out.values()])
    spd = np.mean([v["offload_speedup"] for v in out.values()])
    print(f"mean FC share {share * 100:.1f}% -> offload speedup {spd:.2f}x "
          f"(paper: >50% and ~2x)")
    save("pipeline_split", {"per_attack": out, "mean_fc_share": share,
                            "mean_offload_speedup": spd})


if __name__ == "__main__":
    main()
