import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count at backend
init, and the production meshes need 512 placeholder host devices.

Per cell this driver:
  1. builds the production mesh (16,16) or (2,16,16),
  2. binds arch/shape-conditional sharding rules (distributed/mesh_rules),
  3. lowers the cell's step function with explicit in/out shardings,
  4. compiles, prints memory_analysis() (proves the memory plan) and
     cost_analysis() (FLOPs/bytes for the roofline),
  5. parses the post-SPMD HLO for collective ops -> collective bytes,
  6. writes everything to benchmarks/results/dryrun/<cell>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod     # 2x16x16 only
"""
import argparse
import gc
import json
import re
import traceback
from typing import Dict

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, skip_reason, get_arch
from repro.distributed.flags import use_scan_unroll
from repro.distributed.mesh_rules import make_rules
from repro.distributed.params import (batch_specs, cache_specs, opt_specs,
                                      param_specs)
from repro.distributed.sharding import (AxisRules, named_shardings, set_mesh,
                                        use_rules)
from repro.launch.mesh import make_production_mesh, mesh_shape_dict
from repro.launch.specs import arch_for_cell, input_specs, train_config_for, use_fsdp

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+(all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1,
          "f8e5m2": 1, "s16": 2, "u16": 2}


def _shape_bytes(text: str) -> int:
    """Sum bytes over every shape literal in ``text`` (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind from post-SPMD HLO.

    Result-shape bytes are the per-device payload: for all-reduce this equals
    the operand size; for all-gather it is the post-gather size (an upper
    bound ~n/(n-1) of the wire bytes); '-done' halves of async pairs are
    skipped to avoid double counting.
    """
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_txt, op = m.groups()
        b = _shape_bytes(shape_txt)
        out[op] = out.get(op, 0) + b
        out["total"] = out.get("total", 0) + b
    return out


def _spec_tree_for_cell(kind, cfg, shape, rules, mesh, tc):
    model_size = mesh_shape_dict(mesh).get("model", 1)
    fsdp = 1
    if use_fsdp(cfg):
        fs_axes = rules.rules.get("fsdp")
        if fs_axes:
            md = mesh_shape_dict(mesh)
            fs_axes = (fs_axes,) if isinstance(fs_axes, str) else fs_axes
            fsdp = int(np.prod([md[a] for a in fs_axes]))
    return model_size, fsdp


def _scan_period(cfg) -> int:
    """Layer-pattern period (layers are homogeneous modulo this)."""
    if cfg.family == "hybrid":
        return cfg.attn_every
    if cfg.alt_local_global:
        return 2
    return 1


def _has_layer_scan(cfg) -> bool:
    return cfg.family != "ssm"


def _lower_once(arch: str, shape_name: str, multi_pod: bool, cfg_in,
                unroll: bool, moe_local: bool = False,
                serve_opt: bool = False, fsdp_experts_only: bool = False):
    """Lower + compile one configuration. Returns raw metric dict."""
    import contextlib
    import dataclasses
    shape = SHAPES[shape_name]
    cfg = cfg_in
    mesh = make_production_mesh(multi_pod=multi_pod)
    md = mesh_shape_dict(mesh)
    long_ctx = shape.name == "long_500k"
    rules_dict = make_rules(cfg, shape, multi_pod=multi_pod,
                            model_size=md.get("model", 1),
                            dp_size=int(np.prod([v for k, v in md.items()
                                                 if k != "model"])))
    if fsdp_experts_only:
        rules_dict["fsdp2"] = None    # dense leaves stay TP-resident
    rules = AxisRules(rules_dict)
    model_size, fsdp_size = _spec_tree_for_cell(
        shape.kind, cfg, shape, rules, mesh, None)
    serve_ff_size = 0
    if serve_opt and shape.kind != "train":
        # serving posture: never FSDP-gather weights per step; 2D-shard the
        # expert ffn dim over the DP axes instead (hillclimb: kimi decode)
        fsdp_size = 0
        serve_ff_size = int(np.prod([v for k, v in md.items()
                                     if k != "model"]))

    with use_rules(rules_dict):
        step, args, cfg, tc = input_specs(arch, shape_name, cfg)

        if shape.kind == "train":
            state, batch = args
            pspecs = param_specs(state["params"], cfg, rules, model_size,
                                 fsdp_size)
            ospecs = opt_specs(state["opt"], pspecs, cfg, rules, md, tc.zero1)
            sspecs = {"params": pspecs, "opt": ospecs, "step": P()}
            if "ef_err" in state:
                sspecs["ef_err"] = pspecs
            bspecs = batch_specs(cfg, shape, rules)
            in_shardings = (sspecs, bspecs)
            out_shardings = (sspecs, None)
        elif shape.kind == "prefill":
            params, batch = args
            pspecs = param_specs(params, cfg, rules, model_size, fsdp_size,
                                 serve_ff_size)
            bspecs = batch_specs(cfg, shape, rules)
            in_shardings = (pspecs, bspecs)
            out_shardings = None
        else:  # decode
            params, tokens, cache = args
            pspecs = param_specs(params, cfg, rules, model_size, fsdp_size,
                                 serve_ff_size)
            cspecs = cache_specs(cache, cfg, rules, long_context=long_ctx)
            tspec = rules.spec(("batch", None))
            in_shardings = (pspecs, tspec, cspecs)
            out_shardings = (None, cspecs)

        from repro.distributed import flags as _flags
        dp_axes = tuple(a for a in mesh.axis_names if a != "model")
        moe_ctx = (_flags.use_local_moe_dispatch(mesh, dp_axes, "model")
                   if moe_local else contextlib.nullcontext())
        with use_scan_unroll(unroll), moe_ctx, set_mesh(mesh):
            jitted = jax.jit(
                step,
                in_shardings=named_shardings(mesh, in_shardings),
                out_shardings=named_shardings(mesh, out_shardings))
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()

    coll = collective_bytes(hlo)
    n_devices = int(np.prod(list(md.values())))
    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(f"{k}={v}" for k, v in md.items()),
        "multi_pod": multi_pod,
        "n_devices": n_devices,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "train_posture": {
            "optimizer": tc.optimizer, "param_dtype": tc.param_dtype,
            "remat": tc.remat, "zero1": tc.zero1,
            "fsdp": fsdp_size > 1,
        } if shape.kind == "train" else None,
        "memory_analysis": _mem_dict(mem),
        "arg_bytes_per_device": _arg_bytes(args, in_shardings, md),
        "cost_analysis": {k: float(v) for k, v in (cost or {}).items()
                          if np.isscalar(v) and "{" not in k},
        "collective_bytes": coll,
        "hlo_collective_ops": _coll_counts(hlo),
    }
    del compiled, lowered, jitted
    gc.collect()
    return record


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               moe_local: bool = False, serve_opt: bool = False,
               fsdp_experts_only: bool = False):
    """Lower + compile one cell.

    Primary compile uses the production scan form (memory plan + compile
    proof).  For scan-family archs the per-step cost (FLOPs / bytes /
    collective payloads) is derived from two truncated-depth UNROLLED
    lowerings extrapolated linearly in depth — exact because scan layers are
    homogeneous modulo the layer-pattern period (XLA's HloCostAnalysis counts
    while bodies once, so the scanned numbers under-report by ~n_layers).
    """
    import dataclasses
    shape = SHAPES[shape_name]
    cfg = arch_for_cell(arch, shape)
    record = _lower_once(arch, shape_name, multi_pod, cfg, unroll=False,
                         moe_local=moe_local, serve_opt=serve_opt,
                         fsdp_experts_only=fsdp_experts_only)
    record["cost_lowering"] = "scan_raw"

    if _has_layer_scan(cfg):
        p = _scan_period(cfg)
        L = cfg.n_layers
        if L > 2 * p:
            c1 = _lower_once(arch, shape_name, multi_pod,
                             dataclasses.replace(cfg, n_layers=p),
                             unroll=True, moe_local=moe_local,
                             serve_opt=serve_opt,
                             fsdp_experts_only=fsdp_experts_only)
            c2 = _lower_once(arch, shape_name, multi_pod,
                             dataclasses.replace(cfg, n_layers=2 * p),
                             unroll=True, moe_local=moe_local,
                             serve_opt=serve_opt,
                             fsdp_experts_only=fsdp_experts_only)

            def extrap(a: float, b: float) -> float:
                return max(a + (b - a) * (L - p) / p, b)

            cost = {}
            for k in set(c1["cost_analysis"]) & set(c2["cost_analysis"]):
                cost[k] = extrap(c1["cost_analysis"][k],
                                 c2["cost_analysis"][k])
            coll = {}
            for k in set(c1["collective_bytes"]) | set(c2["collective_bytes"]):
                coll[k] = int(extrap(c1["collective_bytes"].get(k, 0),
                                     c2["collective_bytes"].get(k, 0)))
            ops = {}
            for k in set(c1["hlo_collective_ops"]) | set(c2["hlo_collective_ops"]):
                ops[k] = int(round(extrap(c1["hlo_collective_ops"].get(k, 0),
                                          c2["hlo_collective_ops"].get(k, 0))))
            record["cost_analysis_scanned"] = record["cost_analysis"]
            record["collective_bytes_scanned"] = record["collective_bytes"]
            record["cost_analysis"] = cost
            record["collective_bytes"] = coll
            record["hlo_collective_ops"] = ops
            record["cost_lowering"] = f"unrolled_extrapolated(p={p},L={L})"
        else:
            rec_u = _lower_once(arch, shape_name, multi_pod, cfg, unroll=True,
                                moe_local=moe_local, serve_opt=serve_opt,
                         fsdp_experts_only=fsdp_experts_only)
            record["cost_analysis"] = rec_u["cost_analysis"]
            record["collective_bytes"] = rec_u["collective_bytes"]
            record["hlo_collective_ops"] = rec_u["hlo_collective_ops"]
            record["cost_lowering"] = "unrolled_full"
    else:
        record["cost_lowering"] = "python_unrolled"  # xLSTM: already exact
    return record


def _arg_bytes(args, in_shardings, mesh_dict) -> int:
    """Analytic per-device bytes of all inputs under their PartitionSpecs."""
    total = 0
    flat_a = jax.tree_util.tree_leaves(args)
    flat_s = jax.tree_util.tree_leaves(
        in_shardings, is_leaf=lambda x: isinstance(x, P) or x is None)
    for leaf, spec in zip(flat_a, flat_s):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        denom = 1
        if isinstance(spec, P):
            for d in spec:
                for a in (d if isinstance(d, tuple) else (d,)):
                    if a is not None:
                        denom *= mesh_dict.get(a, 1)
        total += n * leaf.dtype.itemsize // max(denom, 1)
    return total


def _mem_dict(mem) -> Dict:
    if mem is None:
        return {}
    out = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "temp_size_in_bytes",
                 "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out:
        out["repr"] = str(mem)[:2000]
    return out


def _coll_counts(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for op in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute"):
        out[op] = len(re.findall(rf"\b{op}\b", hlo_text))
    return out


def run_cells(archs, shapes, meshes, results_dir: str, force: bool = False):
    os.makedirs(results_dir, exist_ok=True)
    summary = []
    for arch in archs:
        for shape_name in shapes:
            reason = skip_reason(get_arch(arch), SHAPES[shape_name])
            if reason:
                fn = os.path.join(results_dir,
                                  f"{arch}__{shape_name}__skip.json")
                with open(fn, "w") as f:
                    json.dump({"arch": arch, "shape": shape_name,
                               "skipped": reason}, f, indent=1)
                print(f"SKIP  {arch:24s} {shape_name:12s} {reason}")
                continue
            for multi_pod in meshes:
                tag = "multipod" if multi_pod else "singlepod"
                fn = os.path.join(results_dir,
                                  f"{arch}__{shape_name}__{tag}.json")
                if os.path.exists(fn) and not force:
                    print(f"CACHED {arch:24s} {shape_name:12s} {tag}")
                    continue
                try:
                    import time
                    t0 = time.time()
                    rec = lower_cell(arch, shape_name, multi_pod)
                    rec["compile_seconds"] = time.time() - t0
                    with open(fn, "w") as f:
                        json.dump(rec, f, indent=1)
                    mem = rec["memory_analysis"]
                    per_dev = (mem.get("argument_size_in_bytes", 0)
                               + mem.get("temp_size_in_bytes", 0)) / 2**30
                    flops = rec["cost_analysis"].get("flops", 0)
                    print(f"OK    {arch:24s} {shape_name:12s} {tag} "
                          f"mem/dev={per_dev:.2f}GiB flops={flops:.3g} "
                          f"coll={rec['collective_bytes'].get('total', 0)/2**30:.2f}GiB "
                          f"[{rec['compile_seconds']:.0f}s]")
                    summary.append(rec)
                except Exception as e:
                    with open(fn + ".err", "w") as f:
                        f.write(traceback.format_exc())
                    print(f"FAIL  {arch:24s} {shape_name:12s} {tag}: {e}")
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true",
                    help="run only the 2x16x16 mesh")
    ap.add_argument("--single-pod", action="store_true",
                    help="run only the 16x16 mesh")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--results", default=RESULTS_DIR)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    if args.multi_pod:
        meshes = [True]
    elif args.single_pod:
        meshes = [False]
    else:
        meshes = [False, True]
    run_cells(archs, shapes, meshes, os.path.abspath(args.results),
              force=args.force)


if __name__ == "__main__":
    main()
