"""Table 3 analog: data-plane resource usage of the feature pipeline.

On Tofino the budget is stages/SRAM/TCAM/meter-ALUs; on TPU the analogous
budget is VMEM residency of the flow tables, the per-packet state touched,
and kernel grid occupancy.  Reported per slot-count so an operator can size
the tables exactly as §3.3's "Configuration" describes.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import save
from repro.core import init_state, N_FEATURES
from repro.core.state import LAMBDAS, N_BI, N_DECAY, N_UNI


def state_bytes(n_slots: int) -> dict:
    st = init_state(n_slots)
    total = sum(np.prod(l.shape) * l.dtype.itemsize
                for l in jax.tree_util.tree_leaves(st))
    uni = sum(np.prod(l.shape) * l.dtype.itemsize
              for l in jax.tree_util.tree_leaves(st["uni"]))
    return {"n_slots": n_slots, "total_bytes": int(total),
            "uni_bytes": int(uni), "bi_bytes": int(total - uni)}


def main():
    rows = [state_bytes(n) for n in (4096, 8192, 65536, 1 << 20)]
    for r in rows:
        print(f"slots={r['n_slots']:8d}  state={r['total_bytes'] / 2**20:9.2f} MiB "
              f"(uni {r['uni_bytes'] / 2**20:7.2f} / bi {r['bi_bytes'] / 2**20:8.2f})")
    kernel = {
        "feature_update_vmem_per_keytype_bytes": int(8192 * N_DECAY * 4 * 4),
        "decay_instances": N_DECAY,
        "key_types": N_UNI + N_BI,
        "features_per_packet": N_FEATURES,
        "lambdas": list(LAMBDAS),
        "note": "16 MiB VMEM/core fits ~260k slots/key-type resident "
                "(4 atoms x 4 decays x f32); Tofino comparison: the paper "
                "uses 100% of TNA pipe-0 stages and 37% SRAM (Table 3)",
    }
    print("feature_update VMEM @8192 slots/key:",
          kernel["feature_update_vmem_per_keytype_bytes"] / 2**20, "MiB")
    print("features/packet:", kernel["features_per_packet"])
    save("resource_usage", {"state": rows, "kernel": kernel})


if __name__ == "__main__":
    main()
