"""End-to-end detection runners: Peregrine vs the Kitsune-style baseline.

The two systems differ ONLY in where sampling happens (Figure 3):

  Peregrine: FC on ALL packets (data plane) -> sample feature RECORDS 1:x
  Kitsune:   sample raw PACKETS 1:x -> FC on the sampled packets only

Both feed the same KitNET.  ``mode`` selects exact vs switch-approximate
arithmetic for the Peregrine data plane (the baseline always computes exact
statistics in software, as the real Kitsune does).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core import compute_features, default_backend, init_state
from repro.core.records import epoch_indices
from repro.detection.kitnet import train_kitnet, score_kitnet
from repro.traffic.generator import to_jnp


def _features(trace, n_slots: int, mode: str, backend: str = None,
              state=None):
    st = state if state is not None else init_state(n_slots)
    pk = to_jnp(trace)
    if backend is None:
        backend = default_backend(mode)
    st, feats = compute_features(st, pk, backend=backend, mode=mode)
    return st, np.asarray(feats)


def run_peregrine(data: Dict, sampling: int, n_slots: int = 8192,
                  mode: str = "switch", train_epoch: int = 1,
                  seed: int = 0, backend: str = None, chunk: int = 8192,
                  md_backend: str = None, md_kw: Dict = None,
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (scores, labels) per sampled feature record of the eval set.

    ``backend`` selects the FC implementation by name
    (serial/scan/pallas/sharded); the default follows the arithmetic mode.
    ``md_backend`` selects the KitNET scoring implementation
    (einsum/pallas, see ``detection.md_backends``; ``md_kw`` carries its
    options, e.g. ``{"bb": 256}``).  The trace is streamed
    through ``DetectionService`` in ``chunk``-sized batches — flow state
    and epoch accounting carry across chunks and each chunk's records are
    scored as they arrive, so only one chunk of features is resident at a
    time.
    """
    # deferred: repro.serving imports this package for its service
    from repro.serving.detect_service import DetectionService
    svc = DetectionService(epoch=train_epoch, n_slots=n_slots, mode=mode,
                           backend=backend, md_backend=md_backend,
                           md_kw=md_kw)
    svc.observe_stream(data["train"], chunk=chunk)
    svc.fit(seed=seed)
    # eval is a fresh capture: restart epoch accounting at the sampling rate
    # (flow tables stay warm), so record indices are eval-local
    svc.epoch = sampling
    svc.reset_stream()
    idx, scores, _ = svc.process_stream(data["eval"], chunk=chunk)
    labels = data["eval"]["label"][idx]
    return scores, labels


def run_kitsune_baseline(data: Dict, sampling: int, n_slots: int = 8192,
                         train_epoch: int = 1, seed: int = 0,
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Packet-sampled baseline: FC sees ONLY the 1:x sampled packets."""
    tr = data["train"]
    ev = data["eval"]
    tr_idx = epoch_indices(len(tr["ts"]), sampling)
    ev_idx = epoch_indices(len(ev["ts"]), sampling,
                           offset=len(tr["ts"]))
    tr_s = {k: v[tr_idx] for k, v in tr.items()}
    ev_s = {k: v[ev_idx] for k, v in ev.items()}
    st, f_train = _features(tr_s, n_slots, "exact")
    sub = epoch_indices(len(f_train), train_epoch)
    net = train_kitnet(f_train[sub], seed=seed)
    st, f_eval = _features(ev_s, n_slots, "exact", state=st)
    labels = ev_s["label"]
    return score_kitnet(net, f_eval), labels
