"""Peregrine feature-atom update as a Pallas TPU kernel — the paper's switch
pipeline on a TPU core.

One grid step processes a *chunk* of packets with the flow table resident in
VMEM; an in-kernel ``fori_loop`` applies, per packet:

    decay(dt) -> atom update (w, LS, SS across the 4 decay instances)
              -> statistics (mu, sigma)

exactly like the MAU pipeline (DESIGN.md §2).  The table tiles stay in VMEM
across grid steps (sequential grid, ``input_output_aliases``) so the state
never round-trips to HBM between chunks.  Dynamic row indexing models the
switch's register-array access; on real TPU this lowers to sublane dynamic
slices — the hillclimbed layout keeps the 4 decay instances contiguous in the
lane dimension (a (slots, 4·3) tile) so each packet touches one row.

Table layout: packed (n_slots, 12) f32 = [last_t*4 | w*4 | ls*4 | ss*4] is
NOT used; we keep four (n_slots, 4) refs — measured better in interpret-mode
sweeps and simpler aliasing.  Validated against the serial oracle
(core/pipeline.py, exact mode, single key type).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.state import LAMBDAS, N_DECAY

_LAM = tuple(LAMBDAS)


def _fc_kernel(lam_ref, slots_ref, ts_ref, len_ref,
               lt_in, w_in, ls_in, ss_in,
               lt_out, w_out, ls_out, ss_out, stats_ref, *,
               chunk: int, n_pkts: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _copy_in():
        lt_out[...] = lt_in[...]
        w_out[...] = w_in[...]
        ls_out[...] = ls_in[...]
        ss_out[...] = ss_in[...]

    lam = lam_ref[...]                                  # (1, 4)

    def body(i, _):
        g = step * chunk + i
        valid = g < n_pkts
        slot = slots_ref[i]
        t = ts_ref[i]
        x = len_ref[i]

        lt = lt_out[pl.ds(slot, 1), :]                  # (1, 4)
        w = w_out[pl.ds(slot, 1), :]
        ls = ls_out[pl.ds(slot, 1), :]
        ss = ss_out[pl.ds(slot, 1), :]

        fresh = lt < 0.0
        dt = jnp.maximum(t - lt, 0.0)
        delta = jnp.where(fresh, 0.0, jnp.exp2(-lam * dt))
        w2 = w * delta + 1.0
        ls2 = ls * delta + x
        ss2 = ss * delta + x * x

        mu = ls2 / w2
        var = jnp.abs(ss2 / w2 - mu * mu)
        sig = jnp.sqrt(var)

        @pl.when(valid)
        def _store():
            lt_out[pl.ds(slot, 1), :] = jnp.full_like(lt, t)
            w_out[pl.ds(slot, 1), :] = w2
            ls_out[pl.ds(slot, 1), :] = ls2
            ss_out[pl.ds(slot, 1), :] = ss2
            stats_ref[pl.ds(i, 1), :] = jnp.concatenate(
                [w2, mu, sig], axis=-1)                 # (1, 12)

        return 0

    jax.lax.fori_loop(0, chunk, body, 0)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def feature_update(table, slots, ts, lens, *, chunk: int = 256,
                   interpret: bool = True):
    """Single-key-type streaming atom update.

    table: {"last_t","w","ls","ss"} each (n_slots, N_DECAY) f32.
    slots (n,) int32; ts/lens (n,) f32.
    Returns (new_table, stats (n, N_DECAY*3) = [w | mu | sigma] per decay).
    """
    n = slots.shape[0]
    n_slots = table["w"].shape[0]
    nc = -(-n // chunk)
    n_pad = nc * chunk
    if n_pad != n:
        slots = jnp.pad(slots, (0, n_pad - n))
        ts = jnp.pad(ts, (0, n_pad - n))
        lens = jnp.pad(lens, (0, n_pad - n))

    kernel = functools.partial(_fc_kernel, chunk=chunk, n_pkts=n)
    tab_spec = pl.BlockSpec((n_slots, N_DECAY), lambda s: (0, 0))
    out = pl.pallas_call(
        kernel,
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((1, N_DECAY), lambda s: (0, 0)),
            pl.BlockSpec((chunk,), lambda s: (s,)),
            pl.BlockSpec((chunk,), lambda s: (s,)),
            pl.BlockSpec((chunk,), lambda s: (s,)),
            tab_spec, tab_spec, tab_spec, tab_spec,
        ],
        out_specs=[tab_spec, tab_spec, tab_spec, tab_spec,
                   pl.BlockSpec((chunk, N_DECAY * 3), lambda s: (s, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((n_slots, N_DECAY), jnp.float32),
            jax.ShapeDtypeStruct((n_slots, N_DECAY), jnp.float32),
            jax.ShapeDtypeStruct((n_slots, N_DECAY), jnp.float32),
            jax.ShapeDtypeStruct((n_slots, N_DECAY), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, N_DECAY * 3), jnp.float32),
        ],
        input_output_aliases={4: 0, 5: 1, 6: 2, 7: 3},
        interpret=interpret,
    )(jnp.asarray(_LAM, jnp.float32)[None, :], slots, ts, lens,
      table["last_t"], table["w"], table["ls"], table["ss"])
    lt, w, ls, ss, stats = out
    new_table = {"last_t": lt, "w": w, "ls": ls, "ss": ss}
    return new_table, stats[:n]
