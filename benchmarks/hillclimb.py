"""§Perf hillclimb driver: lower a cell with a named variant, record the
roofline terms, and append to the iteration log.

  PYTHONPATH=src python -m benchmarks.hillclimb --cell kimi-k2-1t-a32b:train_4k \
      --variant moe_local

Variants:
  baseline     — exactly the sweep configuration (re-lowered)
  moe_local    — shard_map local MoE dispatch (flags.use_local_moe_dispatch)
  serve_opt    — serving posture: no per-step FSDP, 2D expert sharding
  moe_local+serve_opt
"""
import argparse
import json
import os
import time

from benchmarks.roofline import analyse
from benchmarks.common import RESULTS

PERF_DIR = os.path.join(RESULTS, "perf")


def run(cell: str, variant: str, multi_pod: bool = False):
    from repro.launch.dryrun import lower_cell
    arch, shape = cell.split(":")
    opts = dict(moe_local="moe_local" in variant,
                serve_opt="serve_opt" in variant,
                fsdp_experts_only="fsdp_eo" in variant)
    import contextlib
    from repro.distributed import flags as _flags
    rm = None
    for pol in ("none", "dots", "full"):
        if f"remat_{pol}" in variant:
            rm = pol
    ctx = _flags.use_remat_override(rm) if rm else contextlib.nullcontext()
    t0 = time.time()
    with ctx:
        rec = lower_cell(arch, shape, multi_pod, **opts)
    rec["compile_seconds"] = time.time() - t0
    rec["variant"] = variant
    os.makedirs(PERF_DIR, exist_ok=True)
    fn = os.path.join(PERF_DIR, f"{arch}__{shape}__{variant}.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    a = analyse(rec)
    print(json.dumps({k: a[k] for k in
                      ("arch", "shape", "compute_s", "memory_s",
                       "collective_s", "dominant", "useful_ratio",
                       "roofline_fraction", "peak_mem_gib")}, indent=1))
    print("collectives:", rec["hlo_collective_ops"])
    print("coll bytes GiB:", {k: round(v / 2**30, 2)
                              for k, v in rec["collective_bytes"].items()})
    return rec, a


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    run(args.cell, args.variant, args.multi_pod)


if __name__ == "__main__":
    main()
