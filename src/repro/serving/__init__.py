from repro.serving.engine import ServeEngine  # noqa: F401
from repro.serving.detect_service import DetectionService  # noqa: F401
