"""Peregrine core: per-packet feature computation in a fast data plane,
per-epoch feature-record sampling feeding ML detection (the paper's primary
contribution, adapted to TPU — see DESIGN.md §2)."""
from repro.core.state import (  # noqa: F401
    init_state, state_slots, packet_slots, N_FEATURES, FEATURE_NAMES,
    LAMBDAS, N_DECAY, StatePool, available_state_backends,
    init_state_stacked, register_state_backend, slot_collisions,
    state_backend_of, state_config, state_spec_of,
)
from repro.core.pipeline import process_serial  # noqa: F401
from repro.core.parallel import process_parallel  # noqa: F401
from repro.core.sharded import process_sharded  # noqa: F401
from repro.core.bucketed import process_bucketed  # noqa: F401
from repro.core.backends import (  # noqa: F401
    available_backends, compute_features, default_backend, register_backend,
    resolve_backend,
)
from repro.core.records import (  # noqa: F401
    epoch_sample, epoch_indices, packet_sample_indices,
)
