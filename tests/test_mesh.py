"""Multi-device mesh suite (DESIGN.md §12).

Forced-device topology (``--xla_force_host_platform_device_count``) is
fixed at jax backend init, so the N∈{2,4} checks run in SUBPROCESSES via
``tests/mesh_check.py`` — one process per device count, each running the
full battery set (bucketed parity across every attack generator, fused
stream continuity, sketch state, engine tenant placement, ambient
resolution) and printing one ``MESH-OK <battery>`` marker per pass.  The
parametrized tests here assert the markers individually so a single
battery failure is attributed, not smeared across the suite.

Everything that does NOT need a multi-device topology runs in-process:
the seeded non-Hypothesis twins of the cross-bucket combine properties
(tests/test_properties.py needs ``hypothesis``, which not every host
has), the placement-cache device-count keys, and the ``benchmarks.common``
mesh-row save guard.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import arith
from repro.core.parallel import seg_last_scan, seg_linear_scan

TESTS = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(TESTS)
CHECK = os.path.join(TESTS, "mesh_check.py")
BATTERIES = ("ambient", "parity", "fused", "sketch", "engine")
DEVICE_COUNTS = (2, 4)

_RUNS = {}


def _mesh_run(n_devices: int):
    """One subprocess per device count, shared by every battery test (the
    worker prints all markers in one run — compile once, assert many)."""
    if n_devices not in _RUNS:
        env = dict(os.environ)
        env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                             + env.get("PYTHONPATH", ""))
        env.setdefault("JAX_PLATFORMS", "cpu")
        _RUNS[n_devices] = subprocess.run(
            [sys.executable, CHECK, str(n_devices)],
            capture_output=True, text=True, timeout=1800, env=env,
            cwd=ROOT)
    return _RUNS[n_devices]


@pytest.mark.parametrize("n_devices", DEVICE_COUNTS)
@pytest.mark.parametrize("battery", BATTERIES)
def test_forced_mesh_battery(n_devices, battery):
    p = _mesh_run(n_devices)
    marker = f"MESH-OK {battery}"
    assert marker in p.stdout, (
        f"{marker} missing from mesh_check.py {n_devices} "
        f"(exit {p.returncode})\n--- stdout ---\n{p.stdout[-2000:]}"
        f"\n--- stderr ---\n{p.stderr[-4000:]}")


@pytest.mark.parametrize("n_devices", DEVICE_COUNTS)
def test_forced_mesh_run_clean(n_devices):
    p = _mesh_run(n_devices)
    assert p.returncode == 0 and "MESH-DONE" in p.stdout, (
        p.stdout[-2000:], p.stderr[-4000:])


# ---------------------------------------------------------------------------
# seeded non-Hypothesis twins of the cross-bucket combine properties
# (same invariants as tests/test_properties.py, runnable without hypothesis)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("chunks", (2, 4, 8))
def test_seg_scans_ragged_sentinel_tail_prefix_invariant_seeded(chunks,
                                                                seed):
    """Sentinel-padded ragged tails (core/bucketed.py's padding shape)
    must leave the real-row prefix of both chunked scans exactly the
    unpadded flat scan's."""
    rng = np.random.default_rng(1000 * chunks + seed)
    n = int(rng.integers(2, 41))
    seg = np.sort(rng.integers(0, int(rng.integers(1, 6)), n))
    start = np.r_[True, seg[1:] != seg[:-1]]
    delta = rng.uniform(0.1, 1.0, n).astype(np.float32)
    x = rng.uniform(-2, 2, n).astype(np.float32)
    valid = rng.random(n) < 0.5
    pad = (-n) % chunks
    startp = np.r_[start, np.ones(pad, bool)]
    deltap = np.r_[delta, np.zeros(pad, np.float32)]
    xp = np.r_[x, np.zeros(pad, np.float32)]
    validp = np.r_[valid, np.zeros(pad, bool)]

    flat = np.asarray(seg_linear_scan(jnp.asarray(start), jnp.asarray(delta),
                                      jnp.asarray(x)))
    got = np.asarray(seg_linear_scan(jnp.asarray(startp),
                                     jnp.asarray(deltap),
                                     jnp.asarray(xp), chunks=chunks))[:n]
    np.testing.assert_allclose(got, flat, rtol=2e-4, atol=1e-4)

    f_flat, v_flat = seg_last_scan(jnp.asarray(start), jnp.asarray(valid),
                                   jnp.asarray(x))
    f_ch, v_ch = seg_last_scan(jnp.asarray(startp), jnp.asarray(validp),
                               jnp.asarray(xp), chunks=chunks)
    f_flat = np.asarray(f_flat)
    np.testing.assert_array_equal(np.asarray(f_ch)[:n], f_flat)
    np.testing.assert_array_equal(np.asarray(v_ch)[:n][f_flat],
                                  np.asarray(v_flat)[f_flat])


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("chunks", (2, 4))
def test_invert_perm_shard_crossing_scatter_seeded(chunks, seed):
    """Sort-by-key → chunked scan → scatter back through one shared
    ``invert_perm``: segments crossing chunk cuts come back in original
    order with the flat scan's values."""
    rng = np.random.default_rng(2000 * chunks + seed)
    n = int(rng.integers(4, 65))
    if n % chunks:
        n += chunks - n % chunks
    keys = rng.integers(0, int(rng.integers(1, 5)), n)
    order = np.argsort(keys, kind="stable")
    inv = np.asarray(arith.invert_perm(jnp.asarray(order)))
    x = rng.uniform(-2, 2, n).astype(np.float32)
    np.testing.assert_array_equal(x[order][inv], x)
    sk = keys[order]
    startk = np.r_[True, sk[1:] != sk[:-1]]
    delta = rng.uniform(0.1, 1.0, n).astype(np.float32)
    args = (jnp.asarray(startk), jnp.asarray(delta[order]),
            jnp.asarray(x[order]))
    flat = np.asarray(seg_linear_scan(*args))[inv]
    ch = np.asarray(seg_linear_scan(*args, chunks=chunks))[inv]
    np.testing.assert_allclose(ch, flat, rtol=2e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# placement cache keys include the device count
# ---------------------------------------------------------------------------
def test_shard_ctx_cache_keys_on_device_count():
    """A re-bound mesh under a different forced-device topology must
    never be served a stale compiled step: the ShardContext and the jitted
    bucketed runner are cached per device count on top of the mesh/rule."""
    from repro.core.bucketed import _bucketed_jit, _shard_ctx

    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    c1 = _shard_ctx(mesh, "data", 1)
    c2 = _shard_ctx(mesh, "data", 2)
    assert c1 is not c2
    assert _shard_ctx(mesh, "data", 1) is c1
    assert _shard_ctx(None, "data", 1) is None
    assert _bucketed_jit(4, None, 1) is not _bucketed_jit(4, None, 2)
    assert _bucketed_jit(4, None, 1) is _bucketed_jit(4, None, 1)


def test_fused_placement_token_includes_device_count():
    from repro.serving.fused import _placement_token

    tok = _placement_token()
    assert tok[-1] == jax.device_count()
    assert len(tok) == 4          # flow_shards, tenants, mesh, device count


# ---------------------------------------------------------------------------
# benchmark mesh rows refuse a mismatched forced-device environment
# ---------------------------------------------------------------------------
def test_mesh_bench_rows_refuse_device_mismatch(tmp_path, monkeypatch):
    """``benchmarks.common.save`` must reject a ``_mesh<D>_`` row whose D
    exceeds the device count stamped into the payload's env — committed
    BENCH files can never mix 1- and N-device numbers."""
    sys.path.insert(0, ROOT)
    try:
        from benchmarks import common
    finally:
        sys.path.remove(ROOT)
    monkeypatch.setattr(common, "RESULTS", str(tmp_path / "results"))
    monkeypatch.setattr(common, "ROOT", str(tmp_path))
    ndev = jax.device_count()
    with pytest.raises(ValueError, match="mesh row"):
        common.save("throughput_test",
                    {f"bucketed8_mesh{ndev + 1}_pps": 1.0})
    # rows within the stamped topology save fine (incl. the D=1 baseline)
    fn = common.save("throughput_test",
                     {f"bucketed8_mesh{ndev}_pps": 1.0,
                      "bucketed8_mesh1_pps": 1.0,
                      "scan_pps": 1.0})
    assert os.path.exists(fn)
