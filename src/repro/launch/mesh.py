"""Production mesh construction.

A FUNCTION (not a module constant) so importing this module never touches
jax device state — jax locks the device count at first backend init, and the
dry-run needs to set XLA_FLAGS before that happens.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 2, n_model: int = 4):
    """Small host-device mesh for tests (requires XLA host-device flag)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def mesh_shape_dict(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
