"""gemma2-2b — [dense] 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.
Local(4096-window)/global alternating attention, attn/final logit softcaps,
head_dim=256, embedding scaled by sqrt(d). [arXiv:2408.00118; hf]"""
from repro.configs.base import ArchConfig, DENSE

CONFIG = ArchConfig(
    name="gemma2-2b",
    family=DENSE,
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    window=4096,
    alt_local_global=True,
    attn_softcap=50.0,
    final_softcap=30.0,
    embed_scale=True,
    tie_embeddings=True,
    act="gelu_tanh",
)
