"""Model stacks for all assigned families.

Layer parameters are *stacked* (leading L axis) and executed with
``jax.lax.scan`` so the traced HLO contains a single layer body regardless of
depth — essential to keep 61-layer/1T-param dry-run compiles tractable and to
keep live-HLO size O(1) in depth.

Public entry points (see ``registry.build_model``):
  * ``init_params``   — param pytree (use under ``jax.eval_shape`` for dry-run)
  * ``forward``       — full-sequence forward (train / prefill), returns
                        (logits, aux, cache-or-None)
  * ``decode_step``   — one-token step against a cache
  * ``init_cache``    — cache pytree for a (batch, max_seq)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import base as cfgs
from repro.configs.base import ArchConfig
from repro.distributed.flags import scan_unroll
from repro.distributed.rematctx import maybe_remat
from repro.distributed.sharding import lshard
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import dense_init, embed_init, mlp_fwd, mlp_init, rmsnorm, softcap

Params = Dict[str, Any]


# ===========================================================================
# Init
# ===========================================================================
def init_params(key: jax.Array, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, 8)
    p: Params = {}
    if cfg.embed_inputs:
        p["embed"] = embed_init(keys[0], cfg.vocab, cfg.d_model, dtype)
    else:
        p["in_proj"] = dense_init(keys[0], cfg.d_in, cfg.d_model, dtype)
        p["embed"] = embed_init(keys[6], cfg.vocab, cfg.d_model, dtype)  # for tokens too (vlm mixed)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab, dtype)
    p["final_norm"] = jnp.zeros((cfg.d_model,), dtype)

    if cfg.family in (cfgs.DENSE, cfgs.MOE, cfgs.AUDIO, cfgs.VLM):
        def one_layer(k):
            k1, k2 = jax.random.split(k)
            lp = {"ln1": jnp.zeros((cfg.d_model,), dtype),
                  "ln2": jnp.zeros((cfg.d_model,), dtype),
                  "attn": attn.attn_init(k1, cfg, dtype)}
            if cfg.is_moe:
                lp["moe"] = moe_mod.moe_init(k2, cfg, dtype)
            else:
                lp["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype,
                                     cfg.gated_mlp)
            return lp
        p["layers"] = jax.vmap(one_layer)(jax.random.split(keys[2], cfg.n_layers))
    elif cfg.family == cfgs.HYBRID:
        def one_layer(k):
            return {"ln": jnp.zeros((cfg.d_model,), dtype),
                    "mamba": ssm_mod.mamba2_init(k, cfg, dtype)}
        p["layers"] = jax.vmap(one_layer)(jax.random.split(keys[2], cfg.n_layers))
        k1, k2 = jax.random.split(keys[3])
        p["shared_attn"] = {
            "ln1": jnp.zeros((cfg.d_model,), dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "attn": attn.attn_init(k1, cfg, dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
        }
    elif cfg.family == cfgs.SSM:
        blocks = []
        for i, k in enumerate(jax.random.split(keys[2], cfg.n_layers)):
            init = (xlstm_mod.slstm_init if i in cfg.slstm_at
                    else xlstm_mod.mlstm_init)
            blocks.append({"ln": jnp.zeros((cfg.d_model,), dtype),
                           "cell": init(k, cfg, dtype)})
        p["blocks"] = blocks
    else:
        raise ValueError(cfg.family)
    return p


# ===========================================================================
# Embedding / head
# ===========================================================================
def embed_in(p: Params, cfg: ArchConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    if "embeds" in batch:
        x = jnp.einsum("bsi,id->bsd", batch["embeds"].astype(p["in_proj"].dtype),
                       p["in_proj"])
    else:
        x = jnp.take(p["embed"], batch["tokens"], axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return lshard(x, "batch", "seq", None)


def lm_head(p: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    w = p["embed"].T if cfg.tie_embeddings else p["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    logits = softcap(logits, cfg.final_softcap)
    return lshard(logits, "batch", "seq", "vocab")


# ===========================================================================
# Attention-family stack (dense / moe / audio / vlm)
# ===========================================================================
def _per_layer_windows(cfg: ArchConfig) -> jnp.ndarray:
    """(L,) int32 — effective window per layer (0 = full)."""
    if cfg.alt_local_global:
        w = [cfg.window if (i % 2 == 0) else 0 for i in range(cfg.n_layers)]
    else:
        w = [cfg.window] * cfg.n_layers
    return jnp.asarray(w, jnp.int32)


def _attn_stack_full(p, cfg, x, positions, build_cache: bool, max_seq: int = 0):
    """Full-seq layers via lax.scan. Returns (x, aux, cache_kv or None)."""
    windows = _per_layer_windows(cfg)
    pos1d = positions if positions.ndim == 2 else positions[..., 0]

    def body(carry, xs):
        x, aux = carry
        lp, window = xs
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn.qkv_proj(lp["attn"], h, cfg, positions)
        o = attn.attention(q, k, v, cfg, pos1d, pos1d,
                           causal=cfg.causal, window=window)
        x = x + attn.attn_out(lp["attn"], o)
        x = lshard(x, "batch", "seq", None)
        h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            f, a = moe_mod.moe_ffn(lp["moe"], h2, cfg)
            aux = aux + a
        else:
            f = mlp_fwd(lp["mlp"], h2, cfg.act)
        x = x + f
        x = lshard(x, "batch", "seq", None)
        out = (k, v) if build_cache else None
        return (x, aux), out

    (x, aux), kv = jax.lax.scan(maybe_remat(body), (x, jnp.float32(0.0)),
                                (p["layers"], windows),
                                unroll=True if scan_unroll() else 1)
    cache = None
    if build_cache:
        k_all, v_all = kv                           # (L,B,S,K,hd)
        S = k_all.shape[2]
        if max_seq and max_seq > S:
            padw = ((0, 0), (0, 0), (0, max_seq - S), (0, 0), (0, 0))
            k_all = jnp.pad(k_all, padw)
            v_all = jnp.pad(v_all, padw)
        cache = {"k": lshard(k_all, None, "batch", "kv_seq", "kv_heads", None),
                 "v": lshard(v_all, None, "batch", "kv_seq", "kv_heads", None),
                 "pos": jnp.int32(S)}
    return x, aux, cache


def _attn_stack_decode(p, cfg, x, cache):
    """One-token decode via lax.scan over layers + stacked cache."""
    windows = _per_layer_windows(cfg)
    pos = cache["pos"]                              # scalar int32
    B = x.shape[0]
    if cfg.mrope:
        positions = jnp.broadcast_to(pos, (B, 1))[..., None].repeat(3, -1)
    else:
        positions = jnp.broadcast_to(pos, (B, 1))

    def body(x, xs):
        lp, window, kc, vc = xs
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn.qkv_proj(lp["attn"], h, cfg, positions)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=1)
        o = attn.decode_attention(q, kc, vc, cfg,
                                  jnp.broadcast_to(pos + 1, (B,)), window=window)
        x = x + attn.attn_out(lp["attn"], o)
        h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            f, _ = moe_mod.moe_ffn(lp["moe"], h2, cfg)
        else:
            f = mlp_fwd(lp["mlp"], h2, cfg.act)
        return x + f, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (p["layers"], windows, cache["k"], cache["v"]),
        unroll=True if scan_unroll() else 1)
    new_cache = {"k": k_new, "v": v_new, "pos": pos + 1}
    return x, new_cache


def init_attn_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype) -> Dict:
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.int32(0)}


# ===========================================================================
# Hybrid stack (Zamba2: mamba2 layers + shared attention block)
# ===========================================================================
def _shared_attn_apply(sp, cfg, x, positions, kv_cache, pos):
    """Apply the shared attn+MLP block. kv_cache None => full-seq mode."""
    pos1d = positions if positions.ndim == 2 else positions[..., 0]
    h = rmsnorm(x, sp["ln1"], cfg.norm_eps)
    if kv_cache is None:
        q, k, v = attn.qkv_proj(sp["attn"], h, cfg, positions)
        o = attn.attention(q, k, v, cfg, pos1d, pos1d)
        new_kv = (k, v)
    else:
        kc, vc = kv_cache
        q, k, v = attn.qkv_proj(sp["attn"], h, cfg, positions)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, axis=1)
        B = x.shape[0]
        o = attn.decode_attention(q, kc, vc, cfg,
                                  jnp.broadcast_to(pos + 1, (B,)))
        new_kv = (kc, vc)
    x = x + attn.attn_out(sp["attn"], o)
    h2 = rmsnorm(x, sp["ln2"], cfg.norm_eps)
    return x + mlp_fwd(sp["mlp"], h2, cfg.act), new_kv


def n_attn_apps(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.attn_every if cfg.attn_every else 0


def _hybrid_full(p, cfg, x, positions, build_cache: bool, max_seq: int = 0):
    L = cfg.n_layers
    B, S, _ = x.shape
    apps = n_attn_apps(cfg)
    is_attn = jnp.asarray(
        [1 if (i + 1) % cfg.attn_every == 0 else 0 for i in range(L)], jnp.int32)
    app_idx = jnp.asarray(
        [(i + 1) // cfg.attn_every - 1 if (i + 1) % cfg.attn_every == 0 else 0
         for i in range(L)], jnp.int32)

    kv_shape = (apps, B, max_seq or S, cfg.n_kv_heads, cfg.hd)

    def body(carry, xs):
        # training mode carries only x — the KV buffers are threaded solely
        # when a cache is being built (prefill), saving ~12 GiB/device on the
        # zamba2 train cell (measured via memory_analysis).
        x, kc_all, vc_all = carry if build_cache else (carry, None, None)
        lp, flag, aidx = xs
        h = rmsnorm(x, lp["ln"], cfg.norm_eps)
        m_out, st = ssm_mod.mamba2_fwd(lp["mamba"], h, cfg, None)
        x = x + m_out
        x = lshard(x, "batch", "seq", None)

        def do_attn(op):
            x, kc_all, vc_all = op
            x2, (k, v) = _shared_attn_apply(p["shared_attn"], cfg, x,
                                            positions, None, None)
            if kc_all is None:
                return x2, None, None
            if max_seq and max_seq > S:
                k = jnp.pad(k, ((0, 0), (0, max_seq - S), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, max_seq - S), (0, 0), (0, 0)))
            kc_all = jax.lax.dynamic_update_slice_in_dim(kc_all, k[None], aidx, 0)
            vc_all = jax.lax.dynamic_update_slice_in_dim(vc_all, v[None], aidx, 0)
            return x2, kc_all, vc_all

        x, kc_all, vc_all = jax.lax.cond(flag == 1, do_attn, lambda op: op,
                                         (x, kc_all, vc_all))
        new_carry = (x, kc_all, vc_all) if build_cache else x
        return new_carry, (st["ssm"], st["conv"])

    if build_cache:
        carry0 = (x, jnp.zeros(kv_shape, x.dtype), jnp.zeros(kv_shape, x.dtype))
    else:
        carry0 = x
    carry, (ssm_st, conv_st) = jax.lax.scan(
        maybe_remat(body), carry0, (p["layers"], is_attn, app_idx),
        unroll=True if scan_unroll() else 1)
    cache = None
    if build_cache:
        x, kc, vc = carry
        cache = {"attn_k": kc, "attn_v": vc, "ssm": ssm_st, "conv": conv_st,
                 "pos": jnp.int32(S)}
    else:
        x = carry
    return x, jnp.float32(0.0), cache


def _hybrid_decode(p, cfg, x, cache):
    L = cfg.n_layers
    B = x.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos, (B, 1))
    is_attn = jnp.asarray(
        [1 if (i + 1) % cfg.attn_every == 0 else 0 for i in range(L)], jnp.int32)
    app_idx = jnp.asarray(
        [(i + 1) // cfg.attn_every - 1 if (i + 1) % cfg.attn_every == 0 else 0
         for i in range(L)], jnp.int32)

    def body(carry, xs):
        x, kc_all, vc_all = carry
        lp, flag, aidx, sst, cst = xs
        h = rmsnorm(x, lp["ln"], cfg.norm_eps)
        m_out, st = ssm_mod.mamba2_decode(lp["mamba"], h, cfg,
                                          {"ssm": sst, "conv": cst})
        x = x + m_out

        def do_attn(op):
            x, kc_all, vc_all = op
            kc = jax.lax.dynamic_index_in_dim(kc_all, aidx, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vc_all, aidx, 0, keepdims=False)
            x2, (kc, vc) = _shared_attn_apply(p["shared_attn"], cfg, x,
                                              positions, (kc, vc), pos)
            kc_all = jax.lax.dynamic_update_slice_in_dim(kc_all, kc[None], aidx, 0)
            vc_all = jax.lax.dynamic_update_slice_in_dim(vc_all, vc[None], aidx, 0)
            return x2, kc_all, vc_all

        x, kc_all, vc_all = jax.lax.cond(flag == 1, do_attn, lambda op: op,
                                         (x, kc_all, vc_all))
        return (x, kc_all, vc_all), (st["ssm"], st["conv"])

    (x, kc, vc), (ssm_st, conv_st) = jax.lax.scan(
        body, (x, cache["attn_k"], cache["attn_v"]),
        (p["layers"], is_attn, app_idx, cache["ssm"], cache["conv"]),
        unroll=True if scan_unroll() else 1)
    new_cache = {"attn_k": kc, "attn_v": vc, "ssm": ssm_st, "conv": conv_st,
                 "pos": pos + 1}
    return x, new_cache


def init_hybrid_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype) -> Dict:
    d_inner, nh, hp = ssm_mod.ssm_dims(cfg)
    apps = n_attn_apps(cfg)
    return {
        "attn_k": jnp.zeros((apps, batch, max_seq, cfg.n_kv_heads, cfg.hd), dtype),
        "attn_v": jnp.zeros((apps, batch, max_seq, cfg.n_kv_heads, cfg.hd), dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, nh, hp, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, ssm_mod.CONV_K - 1,
                           d_inner + 2 * cfg.ssm_state), jnp.float32),
        "pos": jnp.int32(0),
    }


# ===========================================================================
# xLSTM stack (unrolled; 12 small layers)
# ===========================================================================
def _xlstm_full(p, cfg, x, build_cache: bool):
    states = []
    for i, blk in enumerate(p["blocks"]):
        h = rmsnorm(x, blk["ln"], cfg.norm_eps)
        if i in cfg.slstm_at:
            out, st = xlstm_mod.slstm_fwd(blk["cell"], h, cfg, None)
        else:
            out, st = xlstm_mod.mlstm_fwd(blk["cell"], h, cfg, None)
        x = x + out
        states.append(st)
    cache = {"states": states, "pos": jnp.int32(x.shape[1])} if build_cache else None
    return x, jnp.float32(0.0), cache


def _xlstm_decode(p, cfg, x, cache):
    new_states = []
    for i, (blk, st) in enumerate(zip(p["blocks"], cache["states"])):
        h = rmsnorm(x, blk["ln"], cfg.norm_eps)
        if i in cfg.slstm_at:
            out, st2 = xlstm_mod.slstm_decode(blk["cell"], h, cfg, st)
        else:
            out, st2 = xlstm_mod.mlstm_decode(blk["cell"], h, cfg, st)
        x = x + out
        new_states.append(st2)
    return x, {"states": new_states, "pos": cache["pos"] + 1}


def init_xlstm_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype) -> Dict:
    states = []
    for i in range(cfg.n_layers):
        if i in cfg.slstm_at:
            states.append(xlstm_mod.slstm_init_state(cfg, batch))
        else:
            states.append(xlstm_mod.mlstm_init_state(cfg, batch))
    return {"states": states, "pos": jnp.int32(0)}


# ===========================================================================
# Public API
# ===========================================================================
def default_positions(cfg: ArchConfig, batch: int, seq: int) -> jax.Array:
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))
    if cfg.mrope:
        pos = pos[..., None].repeat(3, axis=-1)    # stub: t=h=w positions
    return pos


def forward(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            build_cache: bool = False, max_seq: int = 0):
    """Full-sequence forward. Returns (logits, aux_loss, cache|None)."""
    x = embed_in(params, cfg, batch)
    B, S = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = default_positions(cfg, B, S)
    if cfg.family in (cfgs.DENSE, cfgs.MOE, cfgs.AUDIO, cfgs.VLM):
        x, aux, cache = _attn_stack_full(params, cfg, x, positions,
                                         build_cache, max_seq)
    elif cfg.family == cfgs.HYBRID:
        x, aux, cache = _hybrid_full(params, cfg, x, positions,
                                     build_cache, max_seq)
    elif cfg.family == cfgs.SSM:
        x, aux, cache = _xlstm_full(params, cfg, x, build_cache)
    else:
        raise ValueError(cfg.family)
    return lm_head(params, cfg, x), aux, cache


def decode_step(params: Params, cfg: ArchConfig, tokens: jax.Array, cache):
    """tokens: (B, 1) int32. Returns (logits (B,1,V), new_cache)."""
    if cfg.is_encoder:
        raise ValueError("encoder-only model has no decode step")
    x = embed_in(params, cfg, {"tokens": tokens})
    if cfg.family in (cfgs.DENSE, cfgs.MOE, cfgs.VLM):
        x, cache = _attn_stack_decode(params, cfg, x, cache)
    elif cfg.family == cfgs.HYBRID:
        x, cache = _hybrid_decode(params, cfg, x, cache)
    elif cfg.family == cfgs.SSM:
        x, cache = _xlstm_decode(params, cfg, x, cache)
    else:
        raise ValueError(cfg.family)
    return lm_head(params, cfg, x), cache


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    if cfg.family in (cfgs.DENSE, cfgs.MOE, cfgs.VLM):
        return init_attn_cache(cfg, batch, max_seq, dtype)
    if cfg.family == cfgs.HYBRID:
        return init_hybrid_cache(cfg, batch, max_seq, dtype)
    if cfg.family == cfgs.SSM:
        return init_xlstm_cache(cfg, batch, max_seq, dtype)
    raise ValueError(cfg.family)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token CE in fp32. logits (B,S,V), labels (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def lm_loss(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array],
            aux_weight: float = 0.01) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux, _ = forward(params, cfg, batch)
    ce = cross_entropy(logits, batch["labels"], batch.get("mask"))
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}
