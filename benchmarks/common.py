"""Shared benchmark utilities."""
from __future__ import annotations

import json
import os
import re
import time
from typing import Callable, Dict

RESULTS = os.path.join(os.path.dirname(__file__), "results")
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def timeit(fn: Callable, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def env_stamp() -> Dict:
    """Where this measurement ran: numbers from a CPU laptop and a TPU pod
    slice must never be compared as if same-host, so every saved payload
    carries the jax version, platform, and device count it was taken on."""
    import jax
    return {"jax_version": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count()}


_MESH_ROW = re.compile(r"_mesh(\d+)_")


def save(name: str, payload: Dict) -> str:
    payload = {**payload, "env": env_stamp()}
    # a ``<label>_mesh<D>_pps`` row claims a D-device measurement; saving
    # one from a process that never saw D devices (e.g. the forced-device
    # flag was dropped, or a payload is replayed on a smaller host) would
    # commit a 1-device number wearing a mesh label — refuse instead of
    # silently mixing topologies in BENCH_*.json
    ndev = payload["env"]["device_count"]
    for k in payload:
        m = _MESH_ROW.search(str(k))
        if m and int(m.group(1)) > ndev:
            raise ValueError(
                f"mesh row {k!r} claims {m.group(1)} devices but this "
                f"process sees {ndev} — re-run under "
                f"XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{m.group(1)} (or --devices {m.group(1)})")
    os.makedirs(RESULTS, exist_ok=True)
    fn = os.path.join(RESULTS, f"{name}.json")
    with open(fn, "w") as f:
        json.dump(payload, f, indent=1)
    # repo-root snapshot (BENCH_<name>.json): committed alongside the code
    # so the perf trajectory accumulates across PRs instead of living only
    # in benchmarks/results/
    with open(os.path.join(ROOT, f"BENCH_{name}.json"), "w") as f:
        json.dump(payload, f, indent=1)
    return fn


def load(name: str) -> Dict:
    with open(os.path.join(RESULTS, f"{name}.json")) as f:
        return json.load(f)
