"""Mamba-2 (SSD) block in pure JAX — chunked parallel scan for train/prefill,
O(1)-state recurrence for decode.

TPU adaptation: the SSD "chunked" algorithm maps to MXU-friendly einsums
(intra-chunk quadratic + inter-chunk state recurrence via lax.scan with a
(heads, head_dim, state) carry).  Chunk length is a config knob
(``ssm_chunk``; multiples of 128 keep the MXU aligned).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import lshard
from repro.models.layers import dense_init

Params = Dict[str, jax.Array]

CONV_K = 4  # depthwise causal conv kernel width (mamba2 default)


def ssm_dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    nh = cfg.ssm_heads or max(1, d_inner // 64)
    return d_inner, nh, d_inner // nh


def mamba2_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    d_inner, nh, _ = ssm_dims(cfg)
    ds = cfg.ssm_state
    conv_dim = d_inner + 2 * ds
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        # in_proj -> [z, x, B, C, dt]
        "w_in": dense_init(k1, d, 2 * d_inner + 2 * ds + nh, dtype),
        "conv_w": (jax.random.normal(k2, (CONV_K, conv_dim), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),            # A = -exp(A_log)
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),     # softplus ~0.12
        "w_out": dense_init(k3, d_inner, d, dtype),
        "norm_g": jnp.zeros((d_inner,), dtype),            # gated RMSNorm gain
    }


def _split_proj(p: Params, u: jax.Array, cfg: ArchConfig):
    d_inner, nh, _ = ssm_dims(cfg)
    ds = cfg.ssm_state
    zxbcdt = jnp.einsum("bsd,de->bse", u, p["w_in"])
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * ds], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array = None):
    """Depthwise causal conv, kernel CONV_K. xbc: (B,S,C); state: (B,K-1,C)."""
    if state is None:
        pad = jnp.zeros((xbc.shape[0], CONV_K - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)               # (B, S+K-1, C)
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(CONV_K))
    new_state = xp[:, -(CONV_K - 1):, :]
    return jax.nn.silu(out + b[None, None, :]), new_state


def _segsum(x: jax.Array) -> jax.Array:
    """Stable lower-triangular cumulative sums: out[..., i, j] = sum_{j<k<=i} x_k."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, h0=None):
    """SSD parallel form.

    x: (b, S, nh, p); dt: (b, S, nh); A: (nh,) negative; B, C: (b, S, ds).
    Returns y (b, S, nh, p) and final state (b, nh, p, ds).
    """
    b, S, nh, p = x.shape
    ds = B.shape[-1]
    nc = S // chunk
    assert nc * chunk == S, (S, chunk)
    r = lambda t: t.reshape(b, nc, chunk, *t.shape[2:])
    xc, dtc = r(x), r(dt)
    Bc, Cc = r(B), r(C)

    dA = dtc * A[None, None, None, :]                     # (b,nc,Q,nh)
    dA = jnp.transpose(dA, (0, 1, 3, 2))                  # (b,nc,nh,Q)
    dA_cs = jnp.cumsum(dA, axis=-1)

    # -- intra-chunk (quadratic, masked) --
    L = jnp.exp(_segsum(dA))                              # (b,nc,nh,Q,Q)
    CB = jnp.einsum("bcqs,bcks->bcqk", Cc, Bc)            # (b,nc,Q,Q)
    gates = L * CB[:, :, None, :, :]
    xdt = xc * dtc[..., None]                             # (b,nc,Q,nh,p)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", gates, xdt)

    # -- chunk states --
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)       # (b,nc,nh,Q)
    states = jnp.einsum("bcqs,bchq,bcqhp->bchps", Bc, decay_states, xdt)

    # -- inter-chunk recurrence --
    chunk_decay = jnp.exp(dA_cs[..., -1])                 # (b,nc,nh)
    if h0 is None:
        h0 = jnp.zeros((b, nh, p, ds), jnp.float32)

    def step(h, inp):
        cd, st = inp
        h_new = h * cd[..., None, None] + st
        return h_new, h
    sc = jnp.moveaxis(states.astype(jnp.float32), 1, 0)
    cd = jnp.moveaxis(chunk_decay.astype(jnp.float32), 1, 0)
    h_last, h_prevs = jax.lax.scan(step, h0, (cd, sc))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                 # (b,nc,nh,p,ds)

    # -- state -> output --
    state_decay = jnp.exp(dA_cs)                          # (b,nc,nh,Q)
    y_off = jnp.einsum("bcqs,bchps,bchq->bcqhp", Cc,
                       h_prevs.astype(x.dtype), state_decay.astype(x.dtype))
    y = (y_diag + y_off).reshape(b, S, nh, p)
    return y, h_last


def mamba2_fwd(p: Params, u: jax.Array, cfg: ArchConfig,
               state: Dict = None) -> Tuple[jax.Array, Dict]:
    """Full-sequence forward. u: (B, S, d). state: optional initial state."""
    d_inner, nh, hp = ssm_dims(cfg)
    ds = cfg.ssm_state
    z, xbc, dt = _split_proj(p, u, cfg)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    x, B, C = jnp.split(xbc, [d_inner, d_inner + ds], axis=-1)
    x = lshard(x, "batch", "seq", "ssm_inner")
    bsz, S, _ = x.shape
    x = x.reshape(bsz, S, nh, hp)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    h0 = None if state is None else state["ssm"]
    # pad S to a chunk multiple with dt=0 (identity transition, zero input)
    chunk = min(cfg.ssm_chunk, max(8, S)) if S < cfg.ssm_chunk else cfg.ssm_chunk
    Sp = -(-S // chunk) * chunk
    if Sp != S:
        padw = ((0, 0), (0, Sp - S))
        x = jnp.pad(x, padw + ((0, 0), (0, 0)))
        dt = jnp.pad(dt, padw + ((0, 0),))
        B = jnp.pad(B, padw + ((0, 0),))
        C = jnp.pad(C, padw + ((0, 0),))
    y, h_last = ssd_chunked(x.astype(jnp.float32), dt, A,
                            B.astype(jnp.float32), C.astype(jnp.float32),
                            chunk, h0)
    y = y[:, :S]
    x = x[:, :S]
    y = y + x.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(bsz, S, d_inner).astype(u.dtype)
    # gated RMSNorm (mamba2 norm before out-proj)
    y = _gated_rmsnorm(y, z, p["norm_g"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, {"ssm": h_last, "conv": new_conv}


def mamba2_decode(p: Params, u: jax.Array, cfg: ArchConfig,
                  state: Dict) -> Tuple[jax.Array, Dict]:
    """Single-token recurrent step. u: (B, 1, d)."""
    d_inner, nh, hp = ssm_dims(cfg)
    ds = cfg.ssm_state
    z, xbc, dt = _split_proj(p, u, cfg)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], state["conv"])
    x, B, C = jnp.split(xbc, [d_inner, d_inner + ds], axis=-1)
    bsz = x.shape[0]
    x = x.reshape(bsz, nh, hp).astype(jnp.float32)
    B_, C_ = B[:, 0].astype(jnp.float32), C[:, 0].astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A[None, :])                          # (B, nh)
    h = state["ssm"] * dA[..., None, None] + jnp.einsum(
        "bhp,bs->bhps", x * dt[..., None], B_)
    y = jnp.einsum("bhps,bs->bhp", h, C_) + x * p["D"][None, :, None]
    y = y.reshape(bsz, 1, d_inner).astype(u.dtype)
    y = _gated_rmsnorm(y, z, p["norm_g"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, {"ssm": h, "conv": new_conv}


def _gated_rmsnorm(y, z, gain, eps):
    dt = y.dtype
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(var + eps) * (1.0 + gain.astype(jnp.float32))).astype(dt)


def mamba2_init_state(cfg: ArchConfig, batch: int) -> Dict:
    d_inner, nh, hp = ssm_dims(cfg)
    return {
        "ssm": jnp.zeros((batch, nh, hp, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, d_inner + 2 * cfg.ssm_state), jnp.float32),
    }
