"""§5.4 ablation (attacks 5-12 discussion) + the state-backend frontier.

Two approximation axes, one benchmark:

* ``per_attack`` — does the switch's approximate ARITHMETIC hurt
  detection?  Identical traces through exact vs switch FC, AUC per attack
  (the paper conjectures approximation can even act as a regularizer).

* ``state_frontier`` — does the Count-Min SKETCH flow table hurt
  detection, and how fast does accuracy decay with memory?  The same
  traces through ``state_backend="sketch"`` at a ladder of memory budgets
  (total counters per stat table = rows x width), against the dense-exact
  AUC at the top of the ladder.  This is the accuracy-vs-memory frontier a
  switch operator trades against SRAM: dense spends one slot per flow slot
  index, the sketch packs the same stat tables into R hashed rows with
  conservative update (DESIGN.md §11).

``--assert-auc-floor F`` turns the run into a CI gate: exit nonzero unless
the dense-exact AUC AND the largest-budget sketch AUC clear F on every
attack measured — catching both detector regressions and sketch-update
bugs (a broken conservative update tanks AUC long before it breaks shape
checks).
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import save
from repro.detection.sweep import sweep_attack
from repro.traffic import ATTACKS, synth_trace

# memory ladder: (label, n_slots a.k.a. sketch width) at fixed rows=2 —
# totals are 1x / ~1/4x / ~1/16x of the dense 8192-slot table
FULL_BUDGETS = ((4096, 2), (1024, 2), (256, 2))
QUICK_BUDGETS = ((2048, 2), (512, 2), (128, 2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--assert-auc-floor", type=float, default=None,
                    metavar="F",
                    help="exit nonzero unless dense-exact AUC and the "
                         "largest-budget sketch AUC are >= F on every "
                         "attack")
    args = ap.parse_args()
    attacks = (("syn_dos", "ssdp_flood") if args.quick
               else tuple(ATTACKS))
    n = 6000 if args.quick else 30000
    budgets = QUICK_BUDGETS if args.quick else FULL_BUDGETS
    rate = 64
    out = {}
    frontier = {}
    better = 0
    for a in attacks:
        data = synth_trace(a, n_train=n, n_benign_eval=n // 2,
                           n_attack=n // 2, seed=11)
        ex = sweep_attack(data, [rate], mode="exact")["peregrine"][rate]["auc"]
        sw = sweep_attack(data, [rate], mode="switch")["peregrine"][rate]["auc"]
        out[a] = {"exact": ex, "switch": sw, "delta": sw - ex}
        better += sw >= ex
        print(f"{a:18s} exact={ex:.3f} switch={sw:.3f} delta={sw - ex:+.3f}")
        # sketch frontier: exact arithmetic, compressed flow tables
        frontier[a] = {"dense": ex}
        for width, rows in budgets:
            sk = sweep_attack(data, [rate], mode="exact", n_slots=width,
                              state_backend="sketch",
                              state_kw={"rows": rows},
                              )["peregrine"][rate]["auc"]
            frontier[a][f"sketch_r{rows}_w{width}"] = sk
            print(f"{a:18s} sketch rows={rows} width={width:5d} "
                  f"({rows * width:5d} ctrs) auc={sk:.3f} "
                  f"delta={sk - ex:+.3f}")
    print(f"switch >= exact on {better}/{len(attacks)} attacks "
          f"(paper: approximations sometimes improve AUC)")
    save("approx_ablation", {"rate": rate, "per_attack": out,
                             "switch_geq_exact": better,
                             "n_attacks": len(attacks),
                             "budgets_rows_x_width": [
                                 [r, w] for w, r in budgets],
                             "state_frontier": frontier})
    if args.assert_auc_floor is not None:
        floor = args.assert_auc_floor
        width, rows = budgets[0]
        top = f"sketch_r{rows}_w{width}"
        bad = [f"{a}: {k}={frontier[a][k]:.3f}"
               for a in attacks for k in ("dense", top)
               if frontier[a][k] < floor]
        if bad:
            raise SystemExit(f"AUC floor {floor} violated: "
                             + "; ".join(bad))
        print(f"AUC gate: dense and {top} >= {floor} on all "
              f"{len(attacks)} attacks")


if __name__ == "__main__":
    main()
