"""Arch- and shape-conditional binding of logical axes to mesh axes.

The logical-rules indirection keeps arch specialisation in ONE place: e.g.
gemma2-2b has 8 q-heads (< model axis 16) so "heads" binds to None
(attention replicated over TP, FFN still sharded); hubert's vocab 504 is not
divisible by 16 so "vocab" unbinds; long_500k has global_batch 1 so "batch"
unbinds and the KV sequence axis binds to the DP axes instead (sequence
parallelism for the half-megatoken cache).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.sharding import PRODUCTION_RULES


def make_rules(cfg: ArchConfig, shape: Optional[ShapeConfig] = None,
               multi_pod: bool = False, model_size: int = 16,
               dp_size: Optional[int] = None) -> Dict:
    dp_axes: Tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
    r = dict(PRODUCTION_RULES)
    r["batch"] = dp_axes
    r["expert_cap"] = dp_axes
    r["opt"] = dp_axes
    r["fsdp"] = dp_axes
    r["fsdp2"] = dp_axes
    r["serve_ff"] = dp_axes
    if cfg.n_heads % model_size:
        r["heads"] = None
    if cfg.n_kv_heads % model_size:
        r["kv_heads"] = None
    if cfg.vocab % model_size:
        r["vocab"] = None
    ff = cfg.d_ff_expert if cfg.is_moe else cfg.d_ff
    if ff and ff % model_size:
        r["ff"] = None
    if cfg.is_moe and cfg.n_experts % model_size:
        r["experts"] = None
    d_inner = cfg.ssm_expand * cfg.d_model
    if d_inner % model_size:
        r["ssm_inner"] = None
    if shape is not None:
        import numpy as np
        dp = dp_size or (32 if multi_pod else 16)
        if shape.kind == "decode":
            # decode dispatch buffers are tiny (C ~= 8): keep the capacity
            # axis unsharded so it never contends with serve_ff's DP binding
            r["expert_cap"] = None
        if shape.global_batch % dp:
            r["batch"] = None
            r["expert_cap"] = None
            r["opt"] = None
            if shape.kind == "decode":
                # sequence parallelism over the KV cache instead
                r["kv_seq"] = dp_axes
    return r
