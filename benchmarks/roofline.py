"""Roofline analysis from the dry-run's compiled artifacts (deliverable g).

Per (arch x shape) cell on the single-pod 16x16 mesh (multi-pod recorded in
§Dry-run, roofline is single-pod per the assignment):

    compute term    = HLO_FLOPs_per_device / peak_FLOPs        [s]
    memory term     = HLO_bytes_per_device / HBM_bw            [s]
    collective term = collective_bytes_per_device / ICI_bw     [s]

Sources: ``compiled.cost_analysis()`` (flops, bytes accessed — both are for
the per-device partitioned module) and the post-SPMD HLO text parse
(collective result-shape bytes per device) — see launch/dryrun.py.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (we charge one link's bandwidth per chip — conservative;
a 2D-torus chip has more links, so the collective term is an upper bound).

MODEL_FLOPS = 6·N·D (train, fwd+bwd) or 2·N·D (inference), with N = active
params for MoE.  MODEL_FLOPS/HLO_FLOPs exposes remat recompute and
TP-replication waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS = os.path.join(os.path.dirname(__file__), "results")
DRYRUN = os.path.join(RESULTS, "dryrun")


def model_flops_per_device(rec: Dict) -> float:
    """Analytic 6ND / 2ND per device for the cell."""
    from repro.configs import SHAPES, get_arch
    shape = SHAPES[rec["shape"]]
    n_active = rec["active_params"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        factor = 2.0
    else:  # decode: one token per sequence per step
        tokens = shape.global_batch
        factor = 2.0
    return factor * n_active * tokens / rec["n_devices"]


def analyse(rec: Dict) -> Optional[Dict]:
    cost = rec.get("cost_analysis", {})
    flops = cost.get("flops", 0.0)
    mem_bytes = cost.get("bytes accessed", 0.0)
    coll = rec.get("collective_bytes", {}).get("total", 0)
    t_c = flops / PEAK_FLOPS
    t_m = mem_bytes / HBM_BW
    t_n = coll / ICI_BW
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_n}
    dom = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    bound = max(t_c, t_m, t_n)
    # roofline fraction: useful-FLOPs time at peak vs the binding term
    frac = (mf / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dom.replace("_s", ""),
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops,
        "useful_ratio": round(mf / flops, 3) if flops else None,
        "roofline_fraction": round(frac, 4),
        "collective_ops": rec.get("hlo_collective_ops", {}),
        "peak_mem_gib": round(rec.get("memory_analysis", {}).get(
            "peak_memory_in_bytes", 0) / 2**30, 2),
        "suggestion": _suggest(dom, rec),
    }


def _suggest(dom: str, rec: Dict) -> str:
    arch, shape = rec["arch"], rec["shape"]
    if dom == "collective_s":
        ops = rec.get("hlo_collective_ops", {})
        if ops.get("all-gather", 0) > 50:
            return ("FSDP all-gathers dominate: overlap gathers with layer "
                    "compute and/or reduce-scatter grads instead of "
                    "all-reduce+slice")
        return ("shrink TP collective payloads: fuse psums across the "
                "attn+MLP pair or switch batch to more DP / less TP")
    if dom == "memory_s":
        if "decode" in shape or "500k" in shape:
            return ("decode is KV-bandwidth-bound by nature: quantise the "
                    "KV cache (int8) or widen batch to amortise weight reads")
        return ("increase arithmetic intensity: larger per-device batch, "
                "fuse elementwise chains, bf16 activations")
    return ("compute-bound — already in the MXU regime; cut redundant "
            "recompute (remat policy) or TP-replicated attention")


def load_records(pattern: str = "*__singlepod.json") -> List[Dict]:
    out = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN, pattern))):
        with open(fn) as f:
            out.append(json.load(f))
    return out


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | roofline frac | peak GiB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
                 f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
                 f"**{r['dominant']}** | {r['useful_ratio']} | "
                 f"{r['roofline_fraction']:.3f} | {r['peak_mem_gib']} |\n")
    return hdr + body


def main():
    rows = [a for a in (analyse(r) for r in load_records()) if a]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)
    print(markdown_table(rows))
    # flag the three hillclimb candidates
    ranked = sorted(rows, key=lambda r: r["roofline_fraction"])
    coll = sorted(rows, key=lambda r: -r["collective_s"])
    print("\nworst roofline fraction:",
          [(r["arch"], r["shape"], r["roofline_fraction"]) for r in ranked[:3]])
    print("most collective-bound:",
          [(r["arch"], r["shape"], round(r["collective_s"], 3))
           for r in coll[:3]])


if __name__ == "__main__":
    main()
