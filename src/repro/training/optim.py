"""Hand-rolled optimizers (no optax in this environment).

Each optimizer is an (init, update) pair over arbitrary param pytrees.
AdamW is the default; Adafactor provides the low-memory option used by the
kimi-k2 trillion-parameter cell (factored second moment: O(n+m) state per
matrix instead of O(n*m)).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw_init(params, dtype) -> Dict:
    z = lambda p: jnp.zeros(p.shape, dtype)
    return {"m": _tmap(z, params), "v": _tmap(z, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads, opt_state, params, tc: TrainConfig, lr):
    step = opt_state["step"] + 1
    b1, b2 = tc.beta1, tc.beta2
    m = _tmap(lambda m_, g: (b1 * m_.astype(jnp.float32)
                             + (1 - b1) * g.astype(jnp.float32)
                             ).astype(m_.dtype), opt_state["m"], grads)
    v = _tmap(lambda v_, g: (b2 * v_.astype(jnp.float32)
                             + (1 - b2) * jnp.square(g.astype(jnp.float32))
                             ).astype(v_.dtype), opt_state["v"], grads)
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m_, v_):
        mh = m_.astype(jnp.float32) / c1
        vh = v_.astype(jnp.float32) / c2
        delta = mh / (jnp.sqrt(vh) + tc.eps) + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = _tmap(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no momentum) — Shazeer & Stern 2018
# ---------------------------------------------------------------------------
def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params, dtype) -> Dict:
    def zrow(p):
        return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p.shape)
                else jnp.zeros(p.shape, jnp.float32))

    def zcol(p):
        return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factored(p.shape) else jnp.zeros((1,), jnp.float32))

    return {"vr": _tmap(zrow, params), "vc": _tmap(zcol, params),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, opt_state, params, tc: TrainConfig, lr):
    step = opt_state["step"] + 1
    beta2 = 1.0 - step.astype(jnp.float32) ** -0.8

    def upd(p, g, vr, vc):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + 1e-30
        if _factored(p.shape):
            vr2 = beta2 * vr + (1 - beta2) * g2.mean(-1)
            vc2 = beta2 * vc + (1 - beta2) * g2.mean(-2)
            denom = (vr2[..., None] / jnp.maximum(
                vr2.mean(-1, keepdims=True)[..., None], 1e-30)) * vc2[..., None, :]
            u = g / jnp.sqrt(jnp.maximum(denom, 1e-30))
        else:
            vr2 = beta2 * vr + (1 - beta2) * g2
            vc2 = vc
            u = g / jnp.sqrt(jnp.maximum(vr2, 1e-30))
        # relative-scale update clipping
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u)
        scale = jnp.maximum(jnp.sqrt(jnp.mean(jnp.square(
            p.astype(jnp.float32)))), 1e-3)
        new_p = (p.astype(jnp.float32) - lr * scale * u
                 - lr * tc.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), vr2, vc2

    out = _tmap(upd, params, grads, opt_state["vr"], opt_state["vc"])
    leaves, treedef = jax.tree_util.tree_flatten(
        out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    new_params = treedef.unflatten([l[0] for l in leaves])
    vr = treedef.unflatten([l[1] for l in leaves])
    vc = treedef.unflatten([l[2] for l in leaves])
    return new_params, {"vr": vr, "vc": vc, "step": step}


# ---------------------------------------------------------------------------
# SGD (+momentum-free, for small ablations)
# ---------------------------------------------------------------------------
def sgd_init(params, dtype) -> Dict:
    return {"step": jnp.zeros((), jnp.int32)}


def sgd_update(grads, opt_state, params, tc: TrainConfig, lr):
    new_params = _tmap(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                      ).astype(p.dtype), params, grads)
    return new_params, {"step": opt_state["step"] + 1}


def make_optimizer(tc: TrainConfig) -> Tuple[Callable, Callable]:
    dtype = jnp.dtype(tc.opt_state_dtype)
    if tc.optimizer == "adamw":
        return (lambda p: adamw_init(p, dtype),
                lambda g, s, p, lr: adamw_update(g, s, p, tc, lr))
    if tc.optimizer == "adafactor":
        return (lambda p: adafactor_init(p, dtype),
                lambda g, s, p, lr: adafactor_update(g, s, p, tc, lr))
    if tc.optimizer == "sgd":
        return (lambda p: sgd_init(p, dtype),
                lambda g, s, p, lr: sgd_update(g, s, p, tc, lr))
    raise ValueError(tc.optimizer)


def lr_schedule(tc: TrainConfig, step) -> jnp.ndarray:
    """Linear warmup then inverse-sqrt decay."""
    s = jnp.maximum(step.astype(jnp.float32), 1.0)
    warm = tc.learning_rate * s / max(tc.warmup_steps, 1)
    decay = tc.learning_rate * jnp.sqrt(max(tc.warmup_steps, 1) / s)
    return jnp.where(s < tc.warmup_steps, warm, decay)
