"""Bucketed mesh-parallel FC engine — shard count as a throughput axis.

``core/sharded.py`` partitions the *flow tables* and replays the serial
oracle inside each shard: every shard still walks the full packet batch, so
one host pays ~S× the serial work and adding shards *lowers* single-host
throughput (BENCH_throughput.json).  This module partitions the *packets*
instead, on top of the segmented-scan pipeline (``core/parallel.py``):

1. **Compaction.**  The batch is stably sorted by flow hash — the argsort
   by slot the scan backend already pays, no new sort primitives.  Flow
   slots ARE hashes (core/state.py), so the sorted order is a flow-hash
   compaction: every stream is a contiguous run.
2. **Bucketing.**  The compacted batch is cut into S equal slices (a free
   ``(n,) -> (S, n/S)`` reshape).  Buckets are *perfectly balanced by
   construction* — heavy-hitter flows cannot skew them, unlike a
   slot-modulo partition whose worst-case bucket is the whole batch.  The
   price is that at most S-1 streams straddle a cut.
3. **Per-bucket scans.**  Each bucket runs the segmented atom/latest-value
   scans independently (depth O(log n/S) instead of O(log n)); an O(S)
   exclusive combine over per-bucket tail summaries carries the straddling
   streams — the same associative operator, reassociated (results match
   the flat ``scan`` backend to a few ulp; bit-identical at S=1; the
   serial-oracle parity suite holds it to the scan backend's tolerance).
4. **Scatter-back.**  Results return to original packet order through the
   one shared inverse permutation (``core/arith.invert_perm``), exactly as
   the flat scan does.

Placement: on one device the bucket axis is a vectorised batch dimension.
When a mesh is bound and the ``flow_shards`` logical axis has a rule
(distributed/sharding.py), the per-bucket local scans run under
``shard_map`` over that axis — each device scans only its buckets; the
O(S) tail combine and the elementwise fix-up stay outside (they are
negligible).  Ragged batches are padded to a bucket multiple with
sentinel-slot packets that never store back and are never emitted.

``process_bucketed_sampled`` is the record-sampled twin for the fused
serving step (DESIGN.md §8/§9), registered in ``core/backends`` so a
``backend="bucketed"`` service gets the device-resident fast path for free.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax

from repro.core.parallel import _process_parallel_impl
from repro.distributed.sharding import ambient_mesh, flow_shards_binding

try:  # moved out of jax.experimental in newer releases
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover - jax >= 0.6 spelling
    from jax import shard_map


def _resolve_placement(buckets: int):
    """(mesh, binding) for shard_map over the bucket axis, or (None, None).

    Resolved OUTSIDE jit (like core/sharded.py) so the ambient mesh/rule
    participates in the jit cache key — toggling ``use_rules`` retraces
    instead of silently reusing an executable compiled under a different
    placement.  Falls back to single-device vectorisation when no mesh is
    bound, the ``flow_shards`` rule is unbound, the mesh lacks the bound
    axes, or the bucket count does not divide over the axis size.
    """
    binding = flow_shards_binding()
    if binding is None:
        return None, None
    mesh = ambient_mesh()
    if mesh is None:
        return None, None
    axes = binding if isinstance(binding, tuple) else (binding,)
    if not all(a in mesh.axis_names for a in axes):
        return None, None
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    if size < 1 or buckets % size:
        return None, None
    return mesh, binding


@functools.lru_cache(maxsize=None)
def _make_smap(mesh, binding):
    """A transform wrapping the local per-bucket scans in ``shard_map``
    over the bucket (leading) axis.  ``None`` when unplaced — the scans
    then run as a plain vectorised batch dimension on one device.  Cached
    so repeated calls under one placement share jit cache entries.
    """
    if mesh is None:
        return None
    from jax.sharding import PartitionSpec as P
    spec = P(binding)  # leading (bucket) axis sharded, rest replicated

    def smap(fn):
        # the local scans are collective-free (each bucket is independent),
        # so in/out specs are a plain prefix spec over every leaf
        return shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)

    return smap


@functools.lru_cache(maxsize=None)
def _bucketed_jit(buckets: int, mesh, binding):
    smap = _make_smap(mesh, binding)

    @jax.jit
    def run(state, pkts):
        return _process_parallel_impl(state, pkts, chunks=buckets, smap=smap)

    return run


def process_bucketed(state: Dict, pkts: Dict[str, jax.Array],
                     buckets: int = 4, mode: str = "exact"
                     ) -> Tuple[Dict, jax.Array]:
    """Bucketed data-parallel FC: same I/O as ``process_parallel``, the
    batch cut into ``buckets`` balanced flow-hash buckets scanned in
    parallel.  Exact arithmetic only — ``switch`` mode raises; pick the
    ``serial``/``sharded`` oracle backends for the approximated
    arithmetic (they are the only packet-serial paths)."""
    if buckets < 1:
        raise ValueError(f"buckets must be >= 1, got {buckets}")
    if mode != "exact":
        raise ValueError("bucketed backend is exact-mode only")
    mesh, binding = _resolve_placement(buckets)
    return _bucketed_jit(buckets, mesh, binding)(state, pkts)


def process_bucketed_sampled(state: Dict, pkts: Dict[str, jax.Array],
                             sample_idx: jax.Array, buckets: int = 4
                             ) -> Tuple[Dict, jax.Array]:
    """Record-sampled bucketed FC for the fused serving step: state update
    covers every packet, feature rows materialise only at ``sample_idx``
    (row-for-row identical to slicing the full output).  Unjitted — the
    caller (serving/fused.py) inlines it into its own donated jit; the
    ambient placement is resolved at trace time."""
    mesh, binding = _resolve_placement(buckets)
    smap = _make_smap(mesh, binding)
    return _process_parallel_impl(state, pkts, sample_idx,
                                  chunks=buckets, smap=smap)
