"""Architecture & run configuration dataclasses.

Every assigned architecture is expressed as an :class:`ArchConfig`; input
shapes are :class:`ShapeConfig`.  Both are plain frozen dataclasses so they
hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
HYBRID = "hybrid"   # Mamba2 + shared attention blocks (Zamba2)
SSM = "ssm"         # xLSTM (sLSTM + mLSTM blocks)
AUDIO = "audio"     # encoder-only transformer backbone, stub frontend
VLM = "vlm"         # decoder backbone with M-RoPE, stub vision frontend


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                  # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0               # per-expert hidden dim
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # --- attention flavour ---
    causal: bool = True
    window: int = 0                    # 0 -> full attention
    alt_local_global: bool = False     # gemma2: even layers local, odd global
    attn_softcap: float = 0.0          # gemma2 attn logit soft-capping
    final_softcap: float = 0.0         # gemma2 final logit soft-capping
    mrope: bool = False                # qwen2-vl multimodal rope (3 sections)
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    rope_theta: float = 10000.0
    # --- SSM / hybrid ---
    ssm_state: int = 0                 # mamba2 state dim
    ssm_heads: int = 0                 # mamba2 heads (0 -> derived)
    ssm_expand: int = 2
    ssm_chunk: int = 128
    attn_every: int = 0                # hybrid: shared attn block period
    # --- xLSTM ---
    slstm_at: Tuple[int, ...] = ()     # indices of sLSTM blocks; rest mLSTM
    # --- misc ---
    embed_inputs: bool = True          # False -> model consumes embeddings
    embed_scale: bool = False          # gemma2: scale embeddings by sqrt(d)
    d_in: int = 0                      # frontend embedding dim (audio/vlm stub)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"                  # mlp activation: silu|gelu|gelu_tanh
    gated_mlp: bool = True             # False: classic 2-matrix MLP (4d)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encoder(self) -> bool:
        return self.family == AUDIO

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Total parameter count N (analytic; matches init exactly)."""
        d, hd = self.d_model, self.hd
        n_q, n_kv = self.n_heads, self.n_kv_heads
        emb = self.vocab * d if self.embed_inputs else self.d_in * d
        head = 0 if self.tie_embeddings else self.vocab * d
        per_layer = 0
        if self.family in (DENSE, MOE, AUDIO, VLM):
            attn = d * (n_q * hd) + 2 * d * (n_kv * hd) + (n_q * hd) * d
            nm = 3 if self.gated_mlp else 2
            if self.is_moe:
                ff = self.n_experts * 3 * d * self.d_ff_expert + d * self.n_experts
                ff += self.n_shared_experts * 3 * d * self.d_ff_expert
            else:
                ff = nm * d * self.d_ff
            per_layer = attn + ff + 2 * d  # two rmsnorm gains
            total = self.n_layers * per_layer
        elif self.family == HYBRID:
            total = self.n_layers * (_mamba2_params(self) + 2 * d)
            total += _attn_block_params(self)  # one shared block
        elif self.family == SSM:
            total = 0
            for i in range(self.n_layers):
                total += (_slstm_params(self) if i in self.slstm_at
                          else _mlstm_params(self)) + 2 * d
        else:
            raise ValueError(self.family)
        return total + emb + head + d  # final norm

    def active_param_count(self) -> int:
        """Active (per-token) parameters — MoE counts top_k + shared only."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dead = (self.n_experts - self.top_k - self.n_shared_experts)
        return self.param_count() - self.n_layers * dead * 3 * d * self.d_ff_expert


def _mamba2_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    d_inner = cfg.ssm_expand * d
    nh = cfg.ssm_heads or max(1, d_inner // 64)
    # in_proj -> [z, x, B, C, dt] ; out_proj
    return (d * (2 * d_inner + 2 * cfg.ssm_state + nh)
            + d_inner * d + 2 * nh + d_inner)  # A_log, D, dt_bias-ish


def _attn_block_params(cfg: ArchConfig) -> int:
    d, hd = cfg.d_model, cfg.hd
    attn = d * (cfg.n_heads * hd) + 2 * d * (cfg.n_kv_heads * hd) + (cfg.n_heads * hd) * d
    return attn + 3 * d * max(cfg.d_ff, 4 * d) + 2 * d


def _mlstm_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    d_inner = 2 * d
    return d * d_inner * 2 + d_inner * (3 * d_inner) + 3 * d_inner + d_inner * d


def _slstm_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    return 4 * d * d * 2 + 4 * d + 2 * d * int(4 * d * 4 / 3)


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    optimizer: str = "adamw"           # adamw | adafactor | sgd
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    remat: str = "none"                # none | dots | full
    microbatches: int = 1
    zero1: bool = False                # shard optimizer state over DP axis
    grad_compression: str = "none"     # none | int8_ef
    warmup_steps: int = 100
    seed: int = 0


def reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != SSM else 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 1,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=min(cfg.vocab, 512),
        window=min(cfg.window, 64) if cfg.window else 0,
    )
    if cfg.is_moe:
        # capacity_factor high enough that no token ever drops -> decode path
        # is numerically identical to the full pass (tested).
        small.update(n_experts=4, top_k=min(cfg.top_k, 2), d_ff_expert=64,
                     n_shared_experts=min(cfg.n_shared_experts, 1),
                     capacity_factor=4.0)
    if cfg.family == HYBRID:
        small.update(ssm_state=16, ssm_heads=4, ssm_chunk=16, attn_every=2)
    if cfg.family == SSM:
        small.update(slstm_at=tuple(i for i in cfg.slstm_at if i < 2))
    if cfg.family in (AUDIO, VLM):
        small.update(d_in=64 if cfg.d_in else 0)
    if cfg.mrope:
        small.update(mrope_sections=(4, 6, 6))  # sums to head_dim(32)//2
    small.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-reduced", **small)
