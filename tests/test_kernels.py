"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,H,K,Sq,Sk,D", [
    (1, 4, 4, 64, 64, 32),      # MHA square
    (2, 4, 2, 64, 64, 64),      # GQA
    (1, 8, 1, 96, 96, 32),      # MQA, non-multiple of block
    (2, 4, 4, 1, 128, 32),      # decode-like single query
    (1, 2, 2, 200, 72, 64),     # Sq > Sk ragged blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, H, K, Sq, Sk, D, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, K, Sk, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, K, Sk, D)).astype(dtype)
    causal = Sq == Sk
    out = ops.flash_attention(q, k, v, causal=causal, bq=32, bk=32)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window,softcap", [(16, 0.0), (0, 30.0), (24, 50.0)])
def test_flash_attention_window_softcap(window, softcap):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 4, 80, 32))
    k = jax.random.normal(ks[1], (1, 2, 80, 32))
    v = jax.random.normal(ks[2], (1, 2, 80, 32))
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              softcap=softcap, bq=32, bk=32)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                   softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-6)


@pytest.mark.parametrize("n,n_slots,chunk", [(100, 64, 32), (257, 128, 64),
                                             (512, 32, 256)])
def test_feature_update_kernel(n, n_slots, chunk):
    rng = np.random.default_rng(n)
    table = {f: (jnp.zeros((n_slots, 4)) - (1.0 if f == "last_t" else 0.0))
             for f in ("last_t", "w", "ls", "ss")}
    slots = jnp.asarray(rng.integers(0, n_slots, n), jnp.int32)
    ts = jnp.asarray(np.sort(rng.uniform(0, 5, n)), jnp.float32)
    lens = jnp.asarray(rng.integers(60, 1500, n), jnp.float32)
    t1, s1 = ops.feature_update(table, slots, ts, lens, chunk=chunk)
    t2, s2 = ref.feature_update_ref(table, slots, ts, lens)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-3)
    for f in t1:
        np.testing.assert_allclose(np.asarray(t1[f]), np.asarray(t2[f]),
                                   rtol=1e-5, atol=1e-3)


def test_feature_update_warm_table():
    """Carry-in from a warm table must match the serial oracle."""
    rng = np.random.default_rng(0)
    n_slots = 64
    table = {f: (jnp.zeros((n_slots, 4)) - (1.0 if f == "last_t" else 0.0))
             for f in ("last_t", "w", "ls", "ss")}
    for r in range(3):
        n = 150
        slots = jnp.asarray(rng.integers(0, n_slots, n), jnp.int32)
        ts = jnp.asarray(np.sort(rng.uniform(r * 5, r * 5 + 5, n)), jnp.float32)
        lens = jnp.asarray(rng.integers(60, 1500, n), jnp.float32)
        t1, s1 = ops.feature_update(table, slots, ts, lens, chunk=64)
        t2, s2 = ref.feature_update_ref(table, slots, ts, lens)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-5, atol=1e-3)
        table = t1


@pytest.mark.parametrize("B,k,m,h", [(10, 4, 8, 6), (77, 9, 10, 8),
                                     (256, 3, 5, 4)])
def test_kitnet_kernel(B, k, m, h):
    ks = jax.random.split(KEY, 5)
    x = jax.random.uniform(ks[0], (B, k, m))
    w1 = jax.random.normal(ks[1], (k, m, h)) * 0.3
    b1 = jax.random.normal(ks[2], (k, h)) * 0.1
    w2 = jax.random.normal(ks[3], (k, h, m)) * 0.3
    b2 = jax.random.normal(ks[4], (k, m)) * 0.1
    mask = (jax.random.uniform(KEY, (k, m)) > 0.2).astype(jnp.float32)
    r1 = ops.kitnet_ensemble(x, w1, b1, w2, b2, mask, bb=32)
    r2 = ref.kitnet_ensemble_ref(x, w1, b1, w2, b2, mask)
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-6)


def test_flash_matches_model_attention_path():
    """The Pallas kernel and the model's jnp blockwise path agree."""
    from repro.models.attention import blockwise_attention, dense_attention
    from repro.configs import get_arch, reduced
    cfg = reduced(get_arch("deepseek-7b"))
    ks = jax.random.split(KEY, 3)
    B, S, H, D = 2, 64, 4, 32
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, 2, D))
    v = jax.random.normal(ks[2], (B, S, 2, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    d = dense_attention(q, k, v, cfg, pos, pos, causal=True, window=0)
    bw = blockwise_attention(q, k, v, cfg, pos, pos, causal=True, window=0,
                             kv_block=16)
    pl_out = ops.flash_attention(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3),
                                 causal=True, bq=32, bk=32).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(d), np.asarray(bw), atol=2e-5)
    np.testing.assert_allclose(np.asarray(d), np.asarray(pl_out), atol=2e-5)


def test_interpret_env_read_at_call_time(monkeypatch):
    """Regression: REPRO_PALLAS_COMPILE was read once at import time, so
    flipping interpret/compile required a re-import.  Now the env var is
    resolved per call, and an explicit ``interpret=`` always wins."""
    monkeypatch.delenv("REPRO_PALLAS_COMPILE", raising=False)
    assert ops.interpret_default() is True
    monkeypatch.setenv("REPRO_PALLAS_COMPILE", "1")
    assert ops.interpret_default() is False
    # explicit interpret=True overrides the compile request (CPU-safe)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 32, 16))
    k = jax.random.normal(ks[1], (1, 2, 32, 16))
    v = jax.random.normal(ks[2], (1, 2, 32, 16))
    out = ops.flash_attention(q, k, v, causal=True, bq=16, bk=16,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-6)
    monkeypatch.setenv("REPRO_PALLAS_COMPILE", "0")
    assert ops.interpret_default() is True
