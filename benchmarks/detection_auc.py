"""Figures 1 & 7 (+ Appendix B Figures 14-15): detection performance across
sampling rates — Peregrine (switch-mode FC, record sampling) vs the Kitsune
baseline (packet sampling), all 15 attacks.

Full run:  PYTHONPATH=src python -m benchmarks.detection_auc
Quick run: ... --quick  (3 attacks, smaller traces — used by benchmarks.run)
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import save
from repro.detection.sweep import sweep_attack
from repro.traffic import ATTACKS, synth_trace

FULL_RATES = (1, 64, 256, 1024)
QUICK_RATES = (1, 256)


def run(attacks, rates, n_train, n_eval, mode="switch", seed=0,
        state_backend="dense", state_kw=None):
    table = {}
    for attack in attacks:
        t0 = time.time()
        data = synth_trace(attack, n_train=n_train,
                           n_benign_eval=n_eval // 2,
                           n_attack=n_eval // 2, seed=seed)
        table[attack] = sweep_attack(data, rates, mode=mode, seed=seed,
                                     state_backend=state_backend,
                                     state_kw=state_kw)
        p = {r: round(v["auc"], 3) for r, v in table[attack]["peregrine"].items()}
        k = {r: round(v["auc"], 3) for r, v in table[attack]["kitsune"].items()}
        print(f"{attack:18s} peregrine={p} kitsune={k} "
              f"[{time.time() - t0:.0f}s]")
    return table


def summarize(table, rates):
    """Paper-style headline: counts of attacks with AUC > 0.8 / < 0.5."""
    out = {}
    for system in ("peregrine", "kitsune"):
        eff = sum(1 for a in table
                  if min(table[a][system][r]["auc"] for r in rates
                         if r > 1) > 0.8)
        dead = sum(1 for a in table
                   if min(table[a][system][r]["auc"] for r in rates
                          if r > 1) < 0.5)
        out[system] = {"auc>0.8_all_sampled_rates": eff,
                       "auc<0.5_at_some_sampled_rate": dead,
                       "n_attacks": len(table)}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--mode", default="switch", choices=("switch", "exact"))
    ap.add_argument("--state-backend", default="dense",
                    choices=("dense", "sketch"),
                    help="flow-table layout for the Peregrine system "
                         "(sketch forces exact arithmetic)")
    ap.add_argument("--sketch-rows", type=int, default=2,
                    help="Count-Min rows when --state-backend sketch")
    ap.add_argument("--assert-auc-floor", type=float, default=None,
                    metavar="F",
                    help="exit nonzero unless every Peregrine AUC across "
                         "attacks and SAMPLED rates (rate > 1) is >= F "
                         "(rate 1 is excluded, matching the paper's "
                         "headline: unsampled training is the known-"
                         "degenerate corner)")
    args = ap.parse_args()
    mode = args.mode
    state_kw = None
    if args.state_backend == "sketch":
        mode = "exact"      # the sketch implements exact arithmetic only
        state_kw = {"rows": args.sketch_rows}
    if args.quick:
        attacks = ("syn_dos", "ssdp_flood", "mirai")
        rates = QUICK_RATES
        table = run(attacks, rates, n_train=8000, n_eval=12000,
                    mode=mode, state_backend=args.state_backend,
                    state_kw=state_kw)
    else:
        attacks = tuple(ATTACKS)
        rates = FULL_RATES
        table = run(attacks, rates, n_train=60000, n_eval=60000,
                    mode=mode, state_backend=args.state_backend,
                    state_kw=state_kw)
    head = summarize(table, rates)
    print("headline:", head)
    suffix = ("_" + args.state_backend if args.state_backend != "dense"
              else "")
    save("detection_auc" + suffix + ("_quick" if args.quick else ""),
         {"rates": rates, "mode": mode,
          "state_backend": args.state_backend, "state_kw": state_kw,
          "table": table, "headline": head})
    if args.assert_auc_floor is not None:
        floor = args.assert_auc_floor
        gated = [r for r in rates if r > 1]
        bad = [f"{a}@rate{r}: {table[a]['peregrine'][r]['auc']:.3f}"
               for a in table for r in gated
               if table[a]["peregrine"][r]["auc"] < floor]
        if bad:
            raise SystemExit(f"Peregrine AUC floor {floor} violated: "
                             + "; ".join(bad))
        print(f"AUC gate: peregrine >= {floor} on all "
              f"{len(table)} attacks x {len(gated)} sampled rates")


if __name__ == "__main__":
    main()
