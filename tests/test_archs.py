"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs; prefill+decode == full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, reduced, SHAPES, skip_reason
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=24):
    if cfg.embed_inputs:
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
        return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    emb = jax.random.normal(KEY, (B, S, cfg.d_in), jnp.float32)
    lbl = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    return {"embeds": emb, "labels": lbl}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(get_arch(arch))
    model = build_model(cfg)
    params = model.init_params(KEY)
    batch = _batch(cfg)
    logits, aux, _ = model.forward(params, batch)
    B = batch["labels"].shape[0]
    assert logits.shape == (B, 24, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, metrics = model.loss(params, batch)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full(arch):
    cfg = reduced(get_arch(arch))
    if cfg.is_encoder:
        pytest.skip("encoder-only: no decode step")
    model = build_model(cfg)
    params = model.init_params(KEY)
    B, S, Sp = 2, 20, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    full, _, _ = model.forward(params, {"tokens": toks})
    pre, _, cache = model.forward(params, {"tokens": toks[:, :Sp]},
                                  build_cache=True, max_seq=S)
    errs = [np.max(np.abs(np.asarray(
        pre[:, -1:] - full[:, Sp - 1:Sp], dtype=np.float32)))]
    for t in range(Sp, S):
        lg, cache = model.decode_step(params, toks[:, t:t + 1], cache)
        if t < S - 1:
            errs.append(np.max(np.abs(np.asarray(
                lg - full[:, t:t + 1], dtype=np.float32))))
    tol = 1e-4 if cfg.family in ("ssm", "hybrid") else 1e-5
    assert max(errs) < tol, f"{arch}: {max(errs)}"


def test_all_cells_defined():
    """40 cells exist; skips are exactly the documented ones."""
    skips = []
    for arch in ARCHS:
        for sname, shape in SHAPES.items():
            r = skip_reason(get_arch(arch), shape)
            if r:
                skips.append((arch, sname))
    assert len(ARCHS) * len(SHAPES) == 40
    # 7 full-attention long_500k skips + hubert decode_32k + hubert long_500k
    assert len(skips) == 9, skips
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("zamba2-2.7b", "long_500k") not in skips
    assert ("xlstm-125m", "long_500k") not in skips


def test_param_counts_match_headline():
    """Analytic param counts are in the advertised ballpark."""
    expect = {"kimi-k2-1t-a32b": (0.9e12, 1.3e12),
              "phi3.5-moe-42b-a6.6b": (3.5e10, 5.5e10),
              "granite-20b": (1.5e10, 2.5e10),
              "gemma2-2b": (1.5e9, 3.5e9),
              "deepseek-7b": (5e9, 9e9),
              "starcoder2-15b": (1.1e10, 1.9e10),
              "qwen2-vl-72b": (6e10, 9e10),
              "zamba2-2.7b": (1.8e9, 3.6e9),
              "xlstm-125m": (0.8e8, 2.5e8)}
    for name, (lo, hi) in expect.items():
        n = get_arch(name).param_count()
        assert lo <= n <= hi, f"{name}: {n:.3g}"


def test_moe_active_params():
    cfg = get_arch("kimi-k2-1t-a32b")
    active = cfg.active_param_count()
    assert active < 0.1 * cfg.param_count()
    assert 2e10 < active < 6e10  # ~32B active
