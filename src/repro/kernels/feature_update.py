"""Peregrine feature-atom update as a Pallas TPU kernel — the paper's switch
pipeline on a TPU core.

One grid step processes a *chunk* of packets with the flow table resident in
VMEM; an in-kernel ``fori_loop`` applies, per packet:

    decay(dt) -> atom update (w, LS, SS across the 4 decay instances)
              -> statistics (mu, sigma)

exactly like the MAU pipeline (DESIGN.md §2).  The table tiles stay in VMEM
across grid steps (sequential grid, ``input_output_aliases``) so the state
never round-trips to HBM between chunks.  Dynamic row indexing models the
switch's register-array access; on real TPU this lowers to sublane dynamic
slices — the hillclimbed layout keeps the 4 decay instances contiguous in the
lane dimension (a (slots, 4·3) tile) so each packet touches one row.

Table layout: packed (n_slots, 12) f32 = [last_t*4 | w*4 | ls*4 | ss*4] is
NOT used; we keep four (n_slots, 4) refs — measured better in interpret-mode
sweeps and simpler aliasing.  Validated against the serial oracle
(core/pipeline.py, exact mode, single key type).

Two kernels live here:

  * ``feature_update``       — the original single-key-type streaming update
    (kept as the minimal reference kernel and for the kernel unit tests);
  * ``feature_update_full``  — the complete Peregrine FC pipeline: all four
    key types, direction-paired bidirectional tables, and the
    SR/magnitude/radius/cov/PCC cross-direction statistics, emitting the
    same (n, N_FEATURES) layout as the serial oracle.  This is the
    ``backend="pallas"`` implementation behind
    ``repro.core.backends.compute_features``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.state import (
    BI_STATS, LAMBDAS, N_BI, N_DECAY, N_FEATURES, N_UNI, UNI_STATS,
    packet_slots, state_slots,
)

_LAM = tuple(LAMBDAS)
_N_US, _N_BS = len(UNI_STATS), len(BI_STATS)


def _fc_kernel(lam_ref, slots_ref, ts_ref, len_ref,
               lt_in, w_in, ls_in, ss_in,
               lt_out, w_out, ls_out, ss_out, stats_ref, *,
               chunk: int, n_pkts: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _copy_in():
        lt_out[...] = lt_in[...]
        w_out[...] = w_in[...]
        ls_out[...] = ls_in[...]
        ss_out[...] = ss_in[...]

    lam = lam_ref[...]                                  # (1, 4)

    def body(i, _):
        g = step * chunk + i
        valid = g < n_pkts
        slot = slots_ref[i]
        t = ts_ref[i]
        x = len_ref[i]

        lt = lt_out[pl.ds(slot, 1), :]                  # (1, 4)
        w = w_out[pl.ds(slot, 1), :]
        ls = ls_out[pl.ds(slot, 1), :]
        ss = ss_out[pl.ds(slot, 1), :]

        fresh = lt < 0.0
        dt = jnp.maximum(t - lt, 0.0)
        delta = jnp.where(fresh, 0.0, jnp.exp2(-lam * dt))
        w2 = w * delta + 1.0
        ls2 = ls * delta + x
        ss2 = ss * delta + x * x

        mu = ls2 / w2
        var = jnp.abs(ss2 / w2 - mu * mu)
        sig = jnp.sqrt(var)

        @pl.when(valid)
        def _store():
            lt_out[pl.ds(slot, 1), :] = jnp.full_like(lt, t)
            w_out[pl.ds(slot, 1), :] = w2
            ls_out[pl.ds(slot, 1), :] = ls2
            ss_out[pl.ds(slot, 1), :] = ss2
            stats_ref[pl.ds(i, 1), :] = jnp.concatenate(
                [w2, mu, sig], axis=-1)                 # (1, 12)

        return 0

    jax.lax.fori_loop(0, chunk, body, 0)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def feature_update(table, slots, ts, lens, *, chunk: int = 256,
                   interpret: bool = True):
    """Single-key-type streaming atom update.

    table: {"last_t","w","ls","ss"} each (n_slots, N_DECAY) f32.
    slots (n,) int32; ts/lens (n,) f32.
    Returns (new_table, stats (n, N_DECAY*3) = [w | mu | sigma] per decay).
    """
    n = slots.shape[0]
    n_slots = table["w"].shape[0]
    nc = -(-n // chunk)
    n_pad = nc * chunk
    if n_pad != n:
        slots = jnp.pad(slots, (0, n_pad - n))
        ts = jnp.pad(ts, (0, n_pad - n))
        lens = jnp.pad(lens, (0, n_pad - n))

    kernel = functools.partial(_fc_kernel, chunk=chunk, n_pkts=n)
    tab_spec = pl.BlockSpec((n_slots, N_DECAY), lambda s: (0, 0))
    out = pl.pallas_call(
        kernel,
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((1, N_DECAY), lambda s: (0, 0)),
            pl.BlockSpec((chunk,), lambda s: (s,)),
            pl.BlockSpec((chunk,), lambda s: (s,)),
            pl.BlockSpec((chunk,), lambda s: (s,)),
            tab_spec, tab_spec, tab_spec, tab_spec,
        ],
        out_specs=[tab_spec, tab_spec, tab_spec, tab_spec,
                   pl.BlockSpec((chunk, N_DECAY * 3), lambda s: (s, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((n_slots, N_DECAY), jnp.float32),
            jax.ShapeDtypeStruct((n_slots, N_DECAY), jnp.float32),
            jax.ShapeDtypeStruct((n_slots, N_DECAY), jnp.float32),
            jax.ShapeDtypeStruct((n_slots, N_DECAY), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, N_DECAY * 3), jnp.float32),
        ],
        input_output_aliases={4: 0, 5: 1, 6: 2, 7: 3},
        interpret=interpret,
    )(jnp.asarray(_LAM, jnp.float32)[None, :], slots, ts, lens,
      table["last_t"], table["w"], table["ls"], table["ss"])
    lt, w, ls, ss, stats = out
    new_table = {"last_t": lt, "w": w, "ls": ls, "ss": ss}
    return new_table, stats[:n]


# ===========================================================================
# Full-feature kernel: all four key types + bidirectional statistics
# ===========================================================================
#
# The table/row layout (uni keys stacked row-wise, bi keys interleaving
# direction as a reshape view, host-precomputed row indices, blocked stat
# emission + ``_BLOCKED_TO_ORACLE`` permutation, VMEM budget) is recorded in
# DESIGN.md §2.  Semantics are ``process_serial(..., mode="exact")``; the
# round-robin "switch" mode is inherently scalar-serial and stays on the
# oracle path.


def _blocked_to_oracle_perm():
    """Column permutation: kernel blocked layout -> oracle feature order."""
    perm = []
    for k in range(N_UNI):
        for d in range(N_DECAY):
            for s in range(_N_US):
                perm.append(k * N_DECAY * _N_US + s * N_DECAY + d)
    off = N_UNI * N_DECAY * _N_US
    for k in range(N_BI):
        for d in range(N_DECAY):
            for s in range(_N_BS):
                perm.append(off + k * N_DECAY * _N_BS + s * N_DECAY + d)
    return tuple(perm)


_BLOCKED_TO_ORACLE = _blocked_to_oracle_perm()


def _safe_div(a, b):
    """Exact-mode division (0 where the divisor is <= 0), delegated to the
    oracle's arithmetic so the two paths can never drift apart."""
    from repro.core import arith
    return arith.div(a, b, "exact")


def _fc_full_kernel(lam_ref, urow_ref, brow_o_ref, brow_p_ref, brow_s_ref,
                    ts_ref, len_ref,
                    ult_i, uw_i, uls_i, uss_i,
                    blt_i, bw_i, bls_i, bss_i, brl_i, bsr_i, bslt_i,
                    ult, uw, uls, uss,
                    blt, bw, bls, bss, brl, bsr, bslt,
                    stats_ref, *, chunk: int, n_pkts: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _copy_in():
        for src, dst in ((ult_i, ult), (uw_i, uw), (uls_i, uls), (uss_i, uss),
                         (blt_i, blt), (bw_i, bw), (bls_i, bls), (bss_i, bss),
                         (brl_i, brl), (bsr_i, bsr), (bslt_i, bslt)):
            dst[...] = src[...]

    lam = lam_ref[...]                                  # (1, N_DECAY)

    def _update(lt, w, ls, ss, t, x):
        """One stream's decay + atom update (exact mode)."""
        fresh = lt < 0.0
        dt = jnp.maximum(t - lt, 0.0)
        delta = jnp.where(fresh, 0.0, jnp.exp2(-lam * dt))
        return w * delta + 1.0, ls * delta + x, ss * delta + x * x

    def _stats(w, ls, ss):
        mu = _safe_div(ls, w)
        var = jnp.abs(_safe_div(ss, w) - mu * mu)
        return mu, var, jnp.sqrt(var)

    def body(i, _):
        g = step * chunk + i
        valid = g < n_pkts
        t = ts_ref[i]
        x = len_ref[i]
        pieces = []

        # ---- unidirectional key types ----
        for ki in range(N_UNI):
            row = urow_ref[i, ki]
            lt = ult[pl.ds(row, 1), :]
            w2, ls2, ss2 = _update(lt, uw[pl.ds(row, 1), :],
                                   uls[pl.ds(row, 1), :],
                                   uss[pl.ds(row, 1), :], t, x)
            mu, var, sig = _stats(w2, ls2, ss2)
            pieces += [w2, mu, sig]

            @pl.when(valid)
            def _store_uni():
                ult[pl.ds(row, 1), :] = jnp.full_like(lt, t)
                uw[pl.ds(row, 1), :] = w2
                uls[pl.ds(row, 1), :] = ls2
                uss[pl.ds(row, 1), :] = ss2

        # ---- bidirectional key types ----
        for ki in range(N_BI):
            orow = brow_o_ref[i, ki]                    # own-direction row
            prow = brow_p_ref[i, ki]                    # opposite-direction
            srow = brow_s_ref[i, ki]                    # SR (channel) row

            lt_o = blt[pl.ds(orow, 1), :]
            w_o, ls_o, ss_o = _update(lt_o, bw[pl.ds(orow, 1), :],
                                      bls[pl.ds(orow, 1), :],
                                      bss[pl.ds(orow, 1), :], t, x)
            mu_o, var_o, sig_o = _stats(w_o, ls_o, ss_o)

            # stale opposite-direction stats (stored values, as on switch)
            w_p = bw[pl.ds(prow, 1), :]
            mu_p, var_p, sig_p = _stats(w_p, bls[pl.ds(prow, 1), :],
                                        bss[pl.ds(prow, 1), :])

            # SR: decayed sum of cross-direction residual products
            sr = bsr[pl.ds(srow, 1), :]
            sr_lt = bslt[pl.ds(srow, 1), :]
            dsr = jnp.where(sr_lt < 0.0, 0.0,
                            jnp.exp2(-lam * jnp.maximum(t - sr_lt, 0.0)))
            r = x - mu_o
            r_opp = brl[pl.ds(prow, 1), :]
            sr2 = sr * dsr + r * r_opp

            mag = jnp.sqrt(mu_o * mu_o + mu_p * mu_p)
            rad = jnp.sqrt(var_o * var_o + var_p * var_p)
            cov = _safe_div(sr2, w_o + w_p)
            pcc = _safe_div(cov, sig_o * sig_p)
            pieces += [w_o, mu_o, sig_o, mag, rad, cov, pcc]

            @pl.when(valid)
            def _store_bi():
                blt[pl.ds(orow, 1), :] = jnp.full_like(lt_o, t)
                bw[pl.ds(orow, 1), :] = w_o
                bls[pl.ds(orow, 1), :] = ls_o
                bss[pl.ds(orow, 1), :] = ss_o
                brl[pl.ds(orow, 1), :] = r
                bsr[pl.ds(srow, 1), :] = sr2
                bslt[pl.ds(srow, 1), :] = jnp.full_like(sr_lt, t)

        row_stats = jnp.concatenate(pieces, axis=-1)    # (1, N_FEATURES)

        @pl.when(valid)
        def _store_stats():
            stats_ref[pl.ds(i, 1), :] = row_stats

        return 0

    jax.lax.fori_loop(0, chunk, body, 0)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "n"))
def _fc_full_call(tables, urow, brow_o, brow_p, brow_s, ts, lens, *,
                  chunk: int, interpret: bool, n: int):
    n_pad = urow.shape[0]
    nc = n_pad // chunk
    rows_u = tables["ult"].shape[0]
    rows_b = tables["blt"].shape[0]
    rows_s = tables["bsr"].shape[0]

    kernel = functools.partial(_fc_full_kernel, chunk=chunk, n_pkts=n)
    spec_u = pl.BlockSpec((rows_u, N_DECAY), lambda s: (0, 0))
    spec_b = pl.BlockSpec((rows_b, N_DECAY), lambda s: (0, 0))
    spec_s = pl.BlockSpec((rows_s, N_DECAY), lambda s: (0, 0))
    spec_rows = pl.BlockSpec((chunk, 2), lambda s: (s, 0))
    spec_pkt = pl.BlockSpec((chunk,), lambda s: (s,))
    tab_specs = [spec_u] * 4 + [spec_b] * 5 + [spec_s] * 2
    tab_shapes = ([jax.ShapeDtypeStruct((rows_u, N_DECAY), jnp.float32)] * 4 +
                  [jax.ShapeDtypeStruct((rows_b, N_DECAY), jnp.float32)] * 5 +
                  [jax.ShapeDtypeStruct((rows_s, N_DECAY), jnp.float32)] * 2)

    out = pl.pallas_call(
        kernel,
        grid=(nc,),
        in_specs=[pl.BlockSpec((1, N_DECAY), lambda s: (0, 0)),
                  spec_rows, spec_rows, spec_rows, spec_rows,
                  spec_pkt, spec_pkt] + tab_specs,
        out_specs=tab_specs + [
            pl.BlockSpec((chunk, N_FEATURES), lambda s: (s, 0))],
        out_shape=tab_shapes + [
            jax.ShapeDtypeStruct((n_pad, N_FEATURES), jnp.float32)],
        input_output_aliases={7 + k: k for k in range(11)},
        interpret=interpret,
    )(jnp.asarray(_LAM, jnp.float32)[None, :], urow, brow_o, brow_p, brow_s,
      ts, lens,
      tables["ult"], tables["uw"], tables["uls"], tables["uss"],
      tables["blt"], tables["bw"], tables["bls"], tables["bss"],
      tables["brl"], tables["bsr"], tables["bslt"])
    stats = out[-1][:n]
    names = ("ult", "uw", "uls", "uss", "blt", "bw", "bls", "bss",
             "brl", "bsr", "bslt")
    return dict(zip(names, out[:-1])), stats


def feature_update_full(state, pkts, *, chunk: int = 256,
                        interpret: bool = True):
    """Full Peregrine FC (all 80 features) as one Pallas pipeline.

    state: the ``init_state`` dict (rr counters pass through untouched —
    round-robin decay belongs to switch mode, which stays on the serial
    oracle).  pkts: raw packet arrays ``{ts, src, dst, sport, dport, proto,
    length}``.  Returns ``(new_state, feats (n, N_FEATURES))`` matching
    ``process_serial(..., mode="exact")`` to float tolerance.
    """
    n_slots = state_slots(state)
    sl = packet_slots(pkts, n_slots)
    ts = pkts["ts"].astype(jnp.float32)
    lens = pkts["length"].astype(jnp.float32)
    n = ts.shape[0]

    # host-side row precomputation (see layout note above)
    key_off = jnp.arange(N_UNI, dtype=jnp.int32) * n_slots
    urow = jnp.stack([sl["src_mac_ip"], sl["src_ip"]], -1) + key_off[None]
    bbase = jnp.stack([sl["channel"], sl["socket"]], -1) + key_off[None]
    d = sl["dir"][:, None]
    brow_o = bbase * 2 + d
    brow_p = bbase * 2 + (1 - d)
    brow_s = bbase

    nc = -(-max(n, 1) // chunk)
    n_pad = nc * chunk
    pad2 = lambda a: jnp.pad(a, ((0, n_pad - n), (0, 0)))
    pad1 = lambda a: jnp.pad(a, (0, n_pad - n))
    tables = {
        "ult": state["uni"]["last_t"].reshape(-1, N_DECAY),
        "uw": state["uni"]["w"].reshape(-1, N_DECAY),
        "uls": state["uni"]["ls"].reshape(-1, N_DECAY),
        "uss": state["uni"]["ss"].reshape(-1, N_DECAY),
        "blt": state["bi"]["last_t"].reshape(-1, N_DECAY),
        "bw": state["bi"]["w"].reshape(-1, N_DECAY),
        "bls": state["bi"]["ls"].reshape(-1, N_DECAY),
        "bss": state["bi"]["ss"].reshape(-1, N_DECAY),
        "brl": state["bi"]["res_last"].reshape(-1, N_DECAY),
        "bsr": state["bi"]["sr"].reshape(-1, N_DECAY),
        "bslt": state["bi"]["sr_last_t"].reshape(-1, N_DECAY),
    }
    new_tab, stats = _fc_full_call(
        tables, pad2(urow), pad2(brow_o), pad2(brow_p), pad2(brow_s),
        pad1(ts), pad1(lens), chunk=chunk, interpret=interpret, n=n)

    feats = jnp.take(stats, jnp.asarray(_BLOCKED_TO_ORACLE), axis=1)
    sh_u = (N_UNI, n_slots, N_DECAY)
    sh_b = (N_BI, n_slots, 2, N_DECAY)
    new_state = {
        "uni": {"last_t": new_tab["ult"].reshape(sh_u),
                "w": new_tab["uw"].reshape(sh_u),
                "ls": new_tab["uls"].reshape(sh_u),
                "ss": new_tab["uss"].reshape(sh_u),
                "rr": state["uni"]["rr"]},
        "bi": {"last_t": new_tab["blt"].reshape(sh_b),
               "w": new_tab["bw"].reshape(sh_b),
               "ls": new_tab["bls"].reshape(sh_b),
               "ss": new_tab["bss"].reshape(sh_b),
               "res_last": new_tab["brl"].reshape(sh_b),
               "sr": new_tab["bsr"].reshape(N_BI, n_slots, N_DECAY),
               "sr_last_t": new_tab["bslt"].reshape(N_BI, n_slots, N_DECAY),
               "rr": state["bi"]["rr"]},
    }
    return new_state, feats
