"""Sequence-parallel decode attention: explicit shard_map LSE combine.

For long-context decode (long_500k) the KV cache is sharded along the
sequence axis across the DP mesh axes.  Each shard computes a *partial*
softmax over its KV slice plus its local (max, denominator); the shards are
combined with the log-sum-exp trick over the mesh — flash-decoding's split-K
schedule mapped onto the ICI domain.

GSPMD derives an equivalent program from the einsum form automatically; this
explicit version exists because (a) it pins the collective schedule (exactly
one psum pair, no accidental all-gather of the cache) and (b) it is the unit
the §Perf collective-term iteration tunes.  Equivalence against
``attention.decode_attention`` is tested on a host-device mesh.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

NEG_INF = -1e30


def _local_partial(q, k, v, start, cache_len, scale):
    """Partial attention over a local KV slice.

    q: (B,H,d); k/v: (B,S_loc,K,d); start: global offset of this slice.
    Returns (acc (B,H,d), m (B,H), l (B,H)).
    """
    B, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k.astype(jnp.float32)) * scale
    t = start + jnp.arange(k.shape[1])[None, :]
    ok = t < cache_len[:, None]
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                  # (B,K,G)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(ok[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return (acc.reshape(B, H, hd), m.reshape(B, H), l.reshape(B, H))


def make_seq_parallel_decode(mesh: Mesh, seq_axes, kv_spec: P, q_spec: P):
    """Build a seq-sharded decode attention fn for the given mesh binding."""
    axis = seq_axes if isinstance(seq_axes, tuple) else (seq_axes,)

    def fn(q, k_cache, v_cache, cache_len):
        B, _, H, hd = q.shape
        scale = 1.0 / math.sqrt(hd)

        def local(qb, kb, vb, cl):
            # index of this shard along the seq axes
            # mesh axis sizes are static; jax.lax.axis_size is newer-jax only
            idx = 0
            for a in axis:
                idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
            S_loc = kb.shape[1]
            start = idx * S_loc
            acc, m, l = _local_partial(qb[:, 0], kb, vb, start, cl, scale)
            # LSE combine across seq shards
            m_glob = jax.lax.pmax(m, axis)
            corr = jnp.exp(m - m_glob)
            l_glob = jax.lax.psum(l * corr, axis)
            acc_glob = jax.lax.psum(acc * corr[..., None], axis)
            out = acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]
            return out[:, None].astype(qb.dtype)

        def local_wrap(qb, kb, vb, cl):
            return local(qb, kb, vb, cl)

        return shard_map(
            local_wrap, mesh=mesh,
            in_specs=(q_spec, kv_spec, kv_spec, P()),
            out_specs=q_spec,
            check_rep=False,
        )(q, k_cache, v_cache, cache_len)

    return fn
