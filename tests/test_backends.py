"""Cross-backend FC equivalence harness.

Every generator in ``traffic.ATTACKS`` (mixed with benign background) runs
through all three registered backends; ``scan`` and ``pallas`` (interpret
mode) must reproduce the serial-exact oracle's features AND updated
flow-table state.  The pallas kernel follows the oracle's per-packet order,
so it is held to tight float tolerance; the segmented-scan backend
reassociates fp32 sums, so pcc cells (near-zero denominators) get the same
statistical tolerance as tests/test_core.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FEATURE_NAMES, N_FEATURES, available_backends,
                        compute_features, init_state, resolve_backend)
from repro.traffic.generator import ATTACKS, benign_trace

N_PKTS = 256
N_SLOTS = 512

_PCC = [i for i, nm in enumerate(FEATURE_NAMES) if nm.endswith(":pcc")]
_NON_PCC = np.setdiff1d(np.arange(N_FEATURES), _PCC)


def _trace(attack: str, seed: int = 0):
    """Benign background + one attack window, truncated to a fixed length
    so every parametrization shares one jit compilation."""
    rng = np.random.default_rng(seed)
    ben = benign_trace(160, 6.0, rng)
    atk = ATTACKS[attack](120, 1.0, 5.0, rng)
    out = {k: np.concatenate([ben[k], atk[k]]) for k in ben}
    order = np.argsort(out["ts"], kind="stable")
    out = {k: v[order][:N_PKTS] for k, v in out.items()}
    assert len(out["ts"]) == N_PKTS, attack
    return {k: jnp.asarray(v) for k, v in out.items() if k != "label"}


@pytest.fixture(scope="module")
def reference():
    cache = {}

    def get(attack):
        if attack not in cache:
            pk = _trace(attack)
            st, feats = compute_features(init_state(N_SLOTS), pk,
                                         backend="serial", mode="exact")
            cache[attack] = (pk, st, np.asarray(feats))
        return cache[attack]

    return get


@pytest.mark.parametrize("backend", ["scan", "pallas"])
@pytest.mark.parametrize("attack", sorted(ATTACKS))
def test_backend_matches_serial_exact(reference, attack, backend):
    pk, st_ref, f_ref = reference(attack)
    kw = {"chunk": 64} if backend == "pallas" else {}
    st_b, f_b = compute_features(init_state(N_SLOTS), pk,
                                 backend=backend, **kw)
    f_b = np.asarray(f_b)
    assert f_b.shape == (N_PKTS, N_FEATURES)
    assert np.isfinite(f_b).all()
    if backend == "pallas":
        np.testing.assert_allclose(f_b, f_ref, rtol=1e-4, atol=1e-3)
        tol = dict(rtol=1e-4, atol=1e-3)
    else:
        ok = np.abs(f_b - f_ref) <= (1.0 + 1e-3 * np.abs(f_ref))
        assert ok[:, _NON_PCC].all(), attack
        assert ok.mean() >= 0.995, (attack, ok.mean())
        tol = dict(rtol=1e-3, atol=1.0)
    for grp in ("uni", "bi"):
        for k in st_ref[grp]:
            if k == "rr":
                continue
            np.testing.assert_allclose(
                np.asarray(st_b[grp][k]), np.asarray(st_ref[grp][k]),
                err_msg=f"{attack}/{grp}/{k}", **tol)


def test_pallas_chunked_batches_match_one_shot():
    """Chunk-boundary state carry: streaming through the pallas backend in
    batches must equal one-shot processing (VMEM-resident table carry)."""
    pk = _trace("mirai")
    _, f_once = compute_features(init_state(N_SLOTS), pk,
                                 backend="pallas", chunk=64)
    st = init_state(N_SLOTS)
    outs = []
    for i in range(0, N_PKTS, 64):
        chunk = {k: v[i:i + 64] for k, v in pk.items()}
        st, f = compute_features(st, chunk, backend="pallas", chunk=32)
        outs.append(np.asarray(f))
    np.testing.assert_allclose(np.concatenate(outs), np.asarray(f_once),
                               rtol=1e-4, atol=1e-3)


def test_registry_names_aliases_and_errors():
    assert {"serial", "scan", "pallas"} <= set(available_backends())
    assert resolve_backend("parallel") == "scan"
    assert resolve_backend("kernel") == "pallas"
    st = init_state(64)
    pk = _trace("syn_dos")
    with pytest.raises(ValueError, match="unknown FC backend"):
        compute_features(st, pk, backend="nope")
    with pytest.raises(ValueError, match="switch"):
        compute_features(st, pk, backend="scan", mode="switch")
    with pytest.raises(ValueError, match="switch"):
        compute_features(st, pk, backend="pallas", mode="switch")


def test_detection_service_backend_selection():
    from repro.serving import DetectionService
    svc = DetectionService(epoch=64, n_slots=N_SLOTS, backend="pallas")
    svc.observe_benign(_trace("mirai"))
    assert svc.pkt_count == N_PKTS
    assert len(svc._train_feats) == 1          # 256 pkts / epoch 64 -> 4 recs
    assert svc._train_feats[0].shape == (4, N_FEATURES)
    # default backend follows the arithmetic mode
    assert DetectionService(n_slots=64).backend == "scan"
    assert DetectionService(n_slots=64, mode="switch").backend == "serial"
    with pytest.raises(ValueError, match="unknown FC backend"):
        DetectionService(n_slots=64, backend="nope")


def test_serial_switch_mode_via_registry():
    st = init_state(N_SLOTS)
    pk = _trace("syn_dos")
    _, feats = compute_features(st, pk, backend="serial", mode="switch")
    f = np.asarray(feats)
    assert f.shape == (N_PKTS, N_FEATURES)
    assert np.isfinite(f).all()
