"""Figure 8 analog: system throughput vs sampling rate.

The paper measures 100G-link packet rates against the ML classifier's
record-processing rate, binary-searching the highest stable rate.  Offline
(CPU-only) we measure the two component rates directly and derive the same
curve:

    stable_pps(rate) = min(FC_pps, MD_records_per_s * rate)

FC_pps is measured for three backends: the serial switch-semantics oracle,
the TPU-native segmented-scan pipeline, and the Pallas feature_update kernel
(interpret mode; on-TPU this is the line-rate path).  The TPU projection for
the parallel pipeline is derived from its roofline bytes (see EXPERIMENTS.md
§Perf — Peregrine pipeline).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save, timeit
from repro.core import init_state, process_parallel, process_serial
from repro.detection.kitnet import score_kitnet, train_kitnet
from repro.kernels import ops
from repro.traffic import synth_trace, to_jnp
from repro.core.state import packet_slots


def fc_rates(n_pkts: int = 20000, n_slots: int = 8192):
    data = synth_trace("mirai", n_train=n_pkts, n_benign_eval=1000,
                       n_attack=1000, seed=0)
    pk = to_jnp(data["train"])
    st = init_state(n_slots)

    t_par = timeit(lambda: jax.block_until_ready(
        process_parallel(st, pk)[1]), reps=3)
    par_pps = n_pkts / t_par

    n_serial = 2000
    pk_s = {k: v[:n_serial] for k, v in pk.items()}
    t_ser = timeit(lambda: jax.block_until_ready(
        process_serial(st, pk_s, mode="switch")[1]), reps=1)
    ser_pps = n_serial / t_ser

    # Pallas kernel (single key-type stream update), interpret mode
    slots = packet_slots(pk, n_slots)["src_ip"]
    table = {f: (jnp.zeros((n_slots, 4)) - (1.0 if f == "last_t" else 0.0))
             for f in ("last_t", "w", "ls", "ss")}
    n_kern = 4096
    t_kern = timeit(lambda: jax.block_until_ready(ops.feature_update(
        table, slots[:n_kern], pk["ts"][:n_kern], pk["length"][:n_kern],
        chunk=512)[1]), reps=1)
    kern_pps = n_kern / t_kern
    return {"parallel_pps": par_pps, "serial_pps": ser_pps,
            "pallas_interpret_pps": kern_pps}


def md_rate(n_train: int = 4000, n_score: int = 8192):
    rng = np.random.default_rng(0)
    feats = rng.random((n_train, 80)).astype(np.float32)
    net = train_kitnet(feats, seed=0)
    batch = rng.random((n_score, 80)).astype(np.float32)
    t = timeit(lambda: score_kitnet(net, batch), reps=3)
    return n_score / t


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    n = 8000 if args.quick else 40000
    fc = fc_rates(n_pkts=n)
    md = md_rate()
    rates = (1, 64, 1024, 32768)
    curve = {r: min(fc["parallel_pps"], md * r) for r in rates}
    out = {**fc, "md_records_per_s": md,
           "stable_pps_at_rate": curve,
           "note": "on-CPU single-core; Fig8 shape: throughput rises with "
                   "sampling rate until FC-bound"}
    for k, v in out.items():
        if isinstance(v, float):
            print(f"{k:26s} {v:12.0f}")
    print("stable pps:", {r: int(v) for r, v in curve.items()})
    save("throughput", out)


if __name__ == "__main__":
    main()
