"""Fused device-resident serving step: staged-vs-fused bit parity on every
attack generator, streaming continuity under donation, the donation
contract itself, and the scan backend's sort-count / NaN-leak regressions
(serving/fused.py, core/parallel.py — DESIGN.md §8)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import init_state
from repro.serving import DetectionService
from repro.traffic import ATTACKS, synth_trace
from repro.traffic.generator import benign_trace

N_PKTS = 256
N_SLOTS = 512
EPOCH = 32


def _copy(state):
    """The documented donation-safe snapshot (DESIGN.md §8): real buffer
    copies, NOT an aliasing identity tree_map."""
    return jax.tree_util.tree_map(jnp.copy, state)


def _trace(attack: str, seed: int = 0):
    """Benign background + one attack window at a fixed length so every
    parametrization shares one fused-step compilation."""
    rng = np.random.default_rng(seed)
    ben = benign_trace(160, 6.0, rng)
    atk = ATTACKS[attack](120, 1.0, 5.0, rng)
    out = {k: np.concatenate([ben[k], atk[k]]) for k in ben}
    order = np.argsort(out["ts"], kind="stable")
    out = {k: v[order][:N_PKTS] for k, v in out.items() if k != "label"}
    assert len(out["ts"]) == N_PKTS, attack
    return out


@pytest.fixture(scope="module")
def svc():
    """One fitted serial-backend service; tests snapshot/restore its state
    with real copies, so the fused steps' donation cannot corrupt it."""
    data = synth_trace("mirai", n_train=768, n_benign_eval=64,
                       n_attack=64, seed=0)
    s = DetectionService(epoch=EPOCH, n_slots=N_SLOTS, mode="exact",
                         backend="serial")
    s.observe_stream(data["train"], chunk=256)
    s.fit(fpr=0.05)
    assert s.fused          # exact mode defaults to the fused path
    return s


# ---------------------------------------------------------------------------
# fused vs staged parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("attack", sorted(ATTACKS))
def test_fused_matches_staged_bit_identical(svc, attack):
    """The one-jit fused step and the legacy staged process() must emit
    bit-identical (global indices, scores, alarms) on a serial-semantics
    backend, for every attack generator."""
    pk = _trace(attack)
    st0, c0 = _copy(svc.state), svc.pkt_count
    i1, s1, a1 = svc.process(pk, fused=False)
    svc.state, svc.pkt_count = st0, c0
    i2, s2, a2 = svc.process(pk, fused=True)
    assert len(i1) > 0                  # 256 pkts / epoch 32: real records
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(s1, s2)
    np.testing.assert_array_equal(a1, a2)


def test_fused_stream_chunked_equals_one_batch(svc):
    """Chunked fused streaming — with state donated and carried on device
    across chunk boundaries, and chunk sizes that straddle epoch
    boundaries — is bit-identical to one fused batch AND to the legacy
    staged stream."""
    data = synth_trace("mirai", n_train=64, n_benign_eval=256,
                       n_attack=256, seed=7)
    ev = {k: v for k, v in data["eval"].items() if k != "label"}
    st0, c0 = _copy(svc.state), svc.pkt_count
    i1, s1, a1 = svc.process(ev, fused=True)
    svc.state, svc.pkt_count = _copy(st0), c0
    i2, s2, a2 = svc.process_stream(ev, chunk=96, fused=True)
    svc.state, svc.pkt_count = st0, c0
    i3, s3, a3 = svc.process_stream(ev, chunk=96, fused=False)
    for a, b in ((i1, i2), (s1, s2), (a1, a2), (i1, i3), (s1, s3), (a1, a3)):
        np.testing.assert_array_equal(a, b)


def test_fused_scan_backend_tracks_staged(svc):
    """The batch `scan` backend through the fused step: global indices
    match exactly, scores to float tolerance (same compiled FC graph, so
    in practice bit-identical — asserted loosely to stay robust across
    XLA versions)."""
    data = synth_trace("mirai", n_train=768, n_benign_eval=128,
                       n_attack=128, seed=1)
    s = DetectionService(epoch=EPOCH, n_slots=N_SLOTS, mode="exact",
                         backend="scan")
    s.observe_stream(data["train"], chunk=256)
    s.fit(fpr=0.05)
    ev = {k: v for k, v in data["eval"].items() if k != "label"}
    st0, c0 = _copy(s.state), s.pkt_count
    i1, s1, a1 = s.process(ev, fused=False)
    s.state, s.pkt_count = st0, c0
    i2, s2, a2 = s.process(ev, fused=True)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_allclose(s1, s2, rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# donation contract
# ---------------------------------------------------------------------------
def test_fused_step_donates_state_and_service_carries_on(svc):
    """After a fused step the previous state handle is consumed; the
    service must continue exclusively from the returned state — staged and
    fused calls keep interleaving without ever touching a stale buffer."""
    old = svc.state
    svc.process(_trace("mirai", seed=9), fused=True)
    assert svc.state is not old
    assert any(l.is_deleted() for l in jax.tree_util.tree_leaves(old))
    # no stale reads afterwards, in either mode and in training observe
    svc.process(_trace("mirai", seed=10), fused=True)
    svc.process(_trace("mirai", seed=11), fused=False)
    svc.observe_benign(_trace("mirai", seed=12))


def test_aliasing_snapshot_is_the_wrong_way(svc):
    """Regression for the documented contract: an identity tree_map keeps
    the doomed buffers, so reading it after a fused step must raise —
    callers snapshot with jnp.copy (see _copy above) instead."""
    alias = jax.tree_util.tree_map(lambda x: x, svc.state)
    svc.process(_trace("syn_dos", seed=3), fused=True)
    with pytest.raises(RuntimeError, match="deleted"):
        np.asarray(jax.tree_util.tree_leaves(alias)[0])


# ---------------------------------------------------------------------------
# on-device epoch gather
# ---------------------------------------------------------------------------
def test_epoch_gather_matches_host_epoch_indices():
    from repro.core.records import epoch_gather, epoch_indices
    for n, epoch, off in [(256, 32, 0), (200, 64, 984), (10, 64, 54),
                          (10, 64, 0), (64, 64, 63), (1, 1, 0)]:
        idx, cnt = epoch_gather(n, epoch, jnp.int32(off % epoch))
        want = epoch_indices(n, epoch, off)
        c = int(cnt)
        assert c == len(want), (n, epoch, off)
        np.testing.assert_array_equal(np.asarray(idx)[:c], want)
        assert idx.shape[0] == max(1, -(-n // epoch))  # static shape


# ---------------------------------------------------------------------------
# scan backend regressions riding along
# ---------------------------------------------------------------------------
def _count_sorts(jaxpr):
    c = 0
    for eq in jaxpr.eqns:
        if eq.primitive.name == "sort":
            c += 1
        for p in eq.params.values():
            for q in (p if isinstance(p, (list, tuple)) else (p,)):
                if hasattr(q, "jaxpr"):
                    c += _count_sorts(q.jaxpr)
    return c


def test_scan_backend_at_most_four_sorts_per_batch():
    """The segmented-scan FC pipeline pays at most one sort per key type
    (vmapped: one uni + one bi sort primitive) — the directional order and
    the res_last store-back are derived, not re-sorted."""
    from repro.core.parallel import _process_parallel_impl
    st = init_state(256)
    pk = {k: jnp.zeros((64,), jnp.int32)
          for k in ("src", "dst", "sport", "dport", "proto")}
    pk["ts"] = jnp.linspace(0.0, 1.0, 64)
    pk["length"] = jnp.ones((64,))
    jaxpr = jax.make_jaxpr(_process_parallel_impl)(st, pk)
    assert _count_sorts(jaxpr.jaxpr) <= 4


def test_seg_last_scan_nan_invalid_rows_contribute_zero():
    """Regression: a fresh segment whose rows are all invalid must carry an
    explicit zero — the old ``xr * 0`` propagated NaN from invalid rows."""
    from repro.core.parallel import seg_last_scan
    seg_start = jnp.array([True, False, True, False])
    valid = jnp.array([True, False, False, False])
    value = jnp.array([5.0, np.nan, np.nan, np.nan])
    found, val = seg_last_scan(seg_start, valid, value)
    np.testing.assert_array_equal(np.asarray(found),
                                  [True, True, False, False])
    v = np.asarray(val)
    assert v[0] == 5.0 and v[1] == 5.0
    assert v[2] == 0.0 and v[3] == 0.0   # NaN here before the fix
