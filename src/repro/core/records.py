"""Feature-record sampling — the paper's key architectural move.

Peregrine computes features for EVERY packet in the data plane and then
samples the *records* (one per epoch of x packets) sent to the ML detector.
The baseline (Kitsune middlebox model) samples *raw packets* before feature
computation.  ``epoch_sample`` implements the former; the latter is simply
slicing the packet arrays before calling the pipeline (see
``detection.kitsune_baseline``).

Beyond-paper samplers (per-flow, reservoir) are provided for ablations.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def epoch_indices(n_packets: int, epoch: int, offset: int = 0) -> np.ndarray:
    """Indices of packets that close an epoch (every ``epoch``-th packet).

    ``offset`` carries the running packet count across batches so epochs are
    continuous over a streamed trace.
    """
    glob = np.arange(n_packets) + offset + 1
    return np.where(glob % epoch == 0)[0]


def epoch_sample(features: jax.Array, epoch: int, offset: int = 0):
    """features: (n, F) per-packet features -> (records (m, F), indices)."""
    idx = epoch_indices(features.shape[0], epoch, offset)
    return features[jnp.asarray(idx)], idx


def epoch_gather(n_packets: int, epoch: int, offset_mod):
    """jit-safe on-device twin of :func:`epoch_indices`.

    ``offset_mod`` is a traced scalar carrying ``offset % epoch`` (only the
    residue matters for boundary placement, so the device never needs the
    full int64 stream position).  Returns ``(idx, count)`` where ``idx`` is
    a fixed-size ``(ceil(n/epoch),)`` int32 vector of within-batch record
    positions, zero-padded past ``count`` — the shape is static, so the
    gather lives inside a fused jit and only the sampled rows ever need to
    cross to the host.
    """
    max_rec = max(1, -(-n_packets // epoch))
    glob = jnp.arange(n_packets, dtype=jnp.int32) + offset_mod + 1
    mask = (glob % epoch) == 0
    idx = jnp.nonzero(mask, size=max_rec, fill_value=0)[0].astype(jnp.int32)
    return idx, mask.sum()


def packet_sample_indices(n_packets: int, rate: int, offset: int = 0) -> np.ndarray:
    """Raw-packet sampling (the baseline's 1:rate pre-FC sampling)."""
    return epoch_indices(n_packets, rate, offset)


def per_flow_epoch_indices(slots: np.ndarray, epoch: int) -> np.ndarray:
    """Beyond-paper: close an epoch every x packets *per flow slot* —
    denser coverage of low-rate flows at equal record budget."""
    if not len(slots):
        return np.zeros((0,), dtype=np.int64)
    order = np.argsort(slots, kind="stable")
    s = slots[order]
    # rank within flow: distance from the segment's first sorted position
    start = np.r_[True, s[1:] != s[:-1]]
    seg_id = np.cumsum(start) - 1
    first_pos = np.flatnonzero(start)
    rank = np.arange(len(s)) - first_pos[seg_id]
    pick = (rank + 1) % epoch == 0
    return np.sort(order[pick])


def reservoir_indices(n_packets: int, budget: int, seed: int = 0) -> np.ndarray:
    """Beyond-paper: uniform reservoir over the batch at fixed record budget."""
    rng = np.random.default_rng(seed)
    if budget >= n_packets:
        return np.arange(n_packets)
    return np.sort(rng.choice(n_packets, size=budget, replace=False))
