"""Train step: loss -> grads (remat/microbatch) -> clip -> optimizer.

Beyond-paper distributed-optimization features, all toggled by TrainConfig:
  * microbatch gradient accumulation via lax.scan (constant live memory)
  * remat policies (none | dots | full) injected into the layer scans
  * int8 error-feedback gradient compression (distributed/compression.py)
  * ZeRO-1 optimizer-state sharding (launch code constrains opt-state specs
    over the DP axis — see distributed/params.py opt_specs)
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, TrainConfig
from repro.distributed.compression import ef_compress
from repro.models.registry import Model
from repro.training.optim import lr_schedule, make_optimizer
from repro.training.rematctx import use_remat


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, tree)


def init_train_state(model: Model, tc: TrainConfig, key) -> Dict:
    params = model.init_params(key, dtype=jnp.dtype(tc.param_dtype))
    opt_init, _ = make_optimizer(tc)
    state = {"params": params, "opt": opt_init(params),
             "step": jnp.zeros((), jnp.int32)}
    if tc.grad_compression == "int8_ef":
        state["ef_err"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def make_train_step(model: Model, tc: TrainConfig):
    _, opt_update = make_optimizer(tc)

    def loss_fn(params, batch):
        p = cast_tree(params, jnp.dtype(tc.compute_dtype))
        with use_remat(tc.remat):
            loss, metrics = model.loss(p, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if tc.microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        # split leading batch dim into microbatches, accumulate via scan
        mb = tc.microbatches

        def resh(x):
            b = x.shape[0]
            return x.reshape(mb, b // mb, *x.shape[1:])

        batches = jax.tree_util.tree_map(resh, batch)
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, mbatch):
            g_acc, l_acc = acc
            (loss, _), grads = grad_fn(params, mbatch)
            g_acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32) / mb, g_acc, grads)
            return (g_acc, l_acc + loss / mb), None

        (grads, loss), _ = jax.lax.scan(body, (zeros, jnp.float32(0.0)),
                                        batches)
        return loss, {"ce": loss, "aux": jnp.float32(0.0)}, grads

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        loss, metrics, grads = compute_grads(state["params"], batch)
        if tc.grad_compression == "int8_ef":
            grads, new_err = ef_compress(grads, state["ef_err"])
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gn, 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale), grads)
        lr = lr_schedule(tc, state["step"])
        new_params, new_opt = opt_update(grads, state["opt"],
                                         state["params"], lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if tc.grad_compression == "int8_ef":
            new_state["ef_err"] = new_err
        out_metrics = {"loss": loss, "grad_norm": gn, "lr": lr, **metrics}
        return new_state, out_metrics

    return train_step
