"""Host data pipeline: synthetic LM token streams, PHV packet batching,
and a background prefetcher.

``lm_batches`` yields shardable {tokens, labels} batches (Zipf-distributed
synthetic corpus with local n-gram structure so losses actually decrease).
``phv_batches`` chunks a packet trace into fixed-size batches for the
feature pipeline (the switch->server record channel).  ``Prefetcher``
overlaps host generation with device compute via a worker thread.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


def lm_batches(vocab: int, batch: int, seq: int, n_batches: int,
               seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Zipf unigrams + a deterministic bigram mixer: predictable structure."""
    rng = np.random.default_rng(seed)
    base = rng.zipf(1.3, size=(n_batches, batch, seq + 1)).astype(np.int64)
    base = base % (vocab - 1) + 1
    for i in range(n_batches):
        toks = base[i]
        # bigram structure: every even position partly determines the next
        toks[:, 1::2] = (toks[:, 0:-1:2] * 31 + 7) % (vocab - 1) + 1
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}


def phv_batches(trace: Dict[str, np.ndarray], batch: int
                ) -> Iterator[Dict[str, np.ndarray]]:
    n = len(trace["ts"])
    for i in range(0, n, batch):
        yield {k: v[i:i + batch] for k, v in trace.items()}


class Prefetcher:
    """Wrap an iterator; a worker thread keeps ``depth`` items ready."""

    _END = object()

    def __init__(self, it: Iterator, depth: int = 2,
                 transform=None):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.transform = transform

        def work():
            try:
                for item in it:
                    self.q.put(self.transform(item) if self.transform else item)
            finally:
                self.q.put(self._END)

        self.thread = threading.Thread(target=work, daemon=True)
        self.thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self._END:
            raise StopIteration
        return item
