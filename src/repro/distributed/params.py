"""Parameter / optimizer-state / batch / cache PartitionSpec inference.

Specs are derived from leaf *paths* in the param pytree (name-based rules:
Megatron-style TP for attention & MLP, EP for MoE experts, replication for
norms and small SSM blocks) and expressed in *logical* axis names resolved
through ``AxisRules`` — the same mechanism the models use for activation
constraints, so params and activations always agree.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.sharding import AxisRules


def _leaf_logical(path: Tuple, leaf, cfg: ArchConfig, model_size: int,
                  fsdp_size: int = 0, serve_ff_size: int = 0):
    """Logical axis names per dimension for one param leaf.

    ``fsdp_size`` > 0 additionally shards one large *unsharded* dim over the
    DP axes ("fsdp" logical name) — ZeRO-3/FSDP posture for >10B archs; the
    per-dim divisibility is checked here so smaller leaves fall back to
    replication automatically.
    """
    names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    last = names[-1]
    stacked = "layers" in names
    nd = leaf.ndim - (1 if stacked else 0)
    dims = leaf.shape[-nd:] if nd else ()

    def fs(dim_idx, name="fsdp2"):
        """FSDP logical axis if that dim is divisible, else None.

        2D (dense/attention/embedding) leaves use 'fsdp2', 3D expert leaves
        use 'fsdp' — separately bindable so the §Perf 'experts-only FSDP'
        variant can keep dense weights TP-resident (their dp-sharded
        contractions otherwise lower to full-output all-reduces).
        """
        if fsdp_size and dims[dim_idx] % fsdp_size == 0 and \
                dims[dim_idx] >= fsdp_size:
            return name
        return None

    def out(*ax):
        ax = list(ax) + [None] * (nd - len(ax))
        if stacked:
            ax = [None] + ax
        return tuple(ax[:leaf.ndim])

    kv_ok = cfg.n_kv_heads * cfg.hd % max(model_size, 1) == 0
    if last == "embed":
        return out("vocab", fs(1))
    if last == "lm_head":
        return out(fs(0), "vocab")
    if last in ("wq",):
        return out(fs(0), "heads")
    if last in ("wk", "wv"):
        return out(fs(0), "kv_heads" if kv_ok else None)
    if last == "wo" and nd == 2 and "attn" in names:
        return out("heads", fs(1))
    if last in ("wi", "wg") and nd == 2:
        return out(fs(0), "ff")
    if last == "wo" and nd == 2:
        return out("ff", fs(1))
    if last in ("wi", "wg") and nd == 3:              # MoE experts (E, d, f)
        if serve_ff_size and dims[2] % serve_ff_size == 0:
            # serving posture: 2D expert sharding (E x f) — fits 1T weights
            # without per-step FSDP gathers (§Perf kimi decode iteration)
            return out("experts", None, "serve_ff")
        return out("experts", fs(1, "fsdp"), None)
    if last == "wo" and nd == 3:                      # (E, f, d)
        if serve_ff_size and dims[1] % serve_ff_size == 0:
            return out("experts", "serve_ff", None)
        return out("experts", fs(1, "fsdp"), None)
    if last == "router":
        return out(None, None)
    # SSM / xLSTM / norms / biases / conv: replicated
    return out()


def param_specs(params, cfg: ArchConfig, rules: AxisRules,
                model_size: int, fsdp_size: int = 0, serve_ff_size: int = 0):
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [rules.spec(_leaf_logical(path, leaf, cfg, model_size, fsdp_size,
                                      serve_ff_size))
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def _shard_over_opt(spec: P, shape, rules: AxisRules, opt_axes,
                    mesh_shape: Dict[str, int]):
    """ZeRO-1: additionally shard an optimizer-state leaf over the DP axis
    along its largest dimension that is unsharded and divisible."""
    opt_size = int(np.prod([mesh_shape[a] for a in opt_axes])) if opt_axes else 1
    if opt_size <= 1:
        return spec
    dims = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for d in dims:
        for a in (d if isinstance(d, tuple) else (d,)):
            if a is not None:
                used.add(a)
    if any(a in used for a in opt_axes):   # FSDP already uses the DP axes
        return spec
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if dims[i] is None and shape[i] % opt_size == 0 and shape[i] >= opt_size:
            dims[i] = opt_axes if len(opt_axes) > 1 else opt_axes[0]
            return P(*dims)
    return spec


def opt_specs(opt_state, params_specs, cfg: ArchConfig, rules: AxisRules,
              mesh_shape: Dict[str, int], zero1: bool):
    """Specs for the optimizer-state pytree ({m, v, step} or adafactor)."""
    opt_axes = rules.rules.get("opt")
    if opt_axes is None:
        zero1 = False
    elif isinstance(opt_axes, str):
        opt_axes = (opt_axes,)

    def like_params(tree):
        flat_p, _ = jax.tree_util.tree_flatten(params_specs)
        flat_t, treedef = jax.tree_util.tree_flatten(tree)
        out = []
        for spec, leaf in zip(flat_p, flat_t):
            if zero1:
                spec = _shard_over_opt(spec, leaf.shape, rules, opt_axes,
                                       mesh_shape)
            out.append(spec)
        return jax.tree_util.tree_unflatten(treedef, out)

    specs = {}
    for k, v in opt_state.items():
        if k == "step":
            specs[k] = P()
        elif k in ("m", "v"):
            specs[k] = like_params(v)
        elif k in ("vr", "vc"):
            # adafactor factored moments: inherit the parent param's spec
            # minus the factored-out dimension (vr drops the last dim, vc the
            # second-to-last) so multi-GB factored states stay sharded.
            drop = -1 if k == "vr" else -2
            flat_p = jax.tree_util.tree_leaves(params_specs)
            flat_t, treedef = jax.tree_util.tree_flatten(v)
            out = []
            for spec, leaf in zip(flat_p, flat_t):
                dims = list(spec)
                if len(dims) >= abs(drop) and leaf.ndim == len(dims) - 1:
                    del dims[drop]
                    out.append(P(*dims))
                else:
                    out.append(P())
            specs[k] = jax.tree_util.tree_unflatten(treedef, out)
        else:
            specs[k] = jax.tree_util.tree_map(lambda l: P(), v)
    return specs


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, rules: AxisRules):
    b = rules.rules.get("batch")
    toks = P(b, None)
    out = {"labels": toks}
    if cfg.embed_inputs:
        out["tokens"] = toks
    else:
        out["embeds"] = P(b, None, None)
    if shape.kind == "decode":
        out = {"tokens": toks}
    return out


def cache_specs(cache, cfg: ArchConfig, rules: AxisRules,
                long_context: bool = False):
    """Specs for the decode cache pytree.

    When the arch's KV heads cannot shard over the model axis (K % TP != 0:
    gemma2 K=4, qwen2-vl/kimi/phi K=8, granite K=1), the cache SEQUENCE axis
    shards over "model" instead — decode attention becomes a seq-parallel
    partial softmax (GSPMD lowers the LSE combine; the explicit schedule is
    distributed/seq_parallel.py).  Without this, a 32k cache with replicated
    KV exceeds per-chip HBM (qwen2-vl decode_32k: 160 GiB/chip replicated ->
    5.3 GiB/chip seq-sharded).
    """
    b = rules.rules.get("batch")
    kvh = rules.rules.get("kv_heads")
    seq = rules.rules.get("batch") if long_context else None
    kv_seq_tp = None if kvh is not None else "model"

    def leaf_spec(path, leaf):
        names = [getattr(p, "key", str(p)) for p in path]
        last = names[-1]
        if last in ("k", "v", "attn_k", "attn_v"):
            # (L_or_apps, B, S, K, hd)
            if long_context:
                return P(None, None, seq, kvh, None)
            return P(None, b, kv_seq_tp, kvh, None)
        if last == "pos":
            return P()
        if last in ("ssm",):
            return P(None, b) if leaf.ndim > 1 else P()
        if last == "conv":
            return P(None, b)
        # xlstm states (no leading layer axis): batch-shard dim 0
        if leaf.ndim >= 1 and last in ("C", "n", "m", "c", "h"):
            return P(b)
        return P(*([None] * leaf.ndim))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [leaf_spec(p, l) for p, l in flat])
