"""Model registry — resolves an ArchConfig into the functional model API."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init_params: Callable
    forward: Callable
    decode_step: Callable
    init_cache: Callable
    loss: Callable


def build_model(cfg: ArchConfig) -> Model:
    return Model(
        cfg=cfg,
        init_params=lambda key, dtype=jnp.float32: tf.init_params(key, cfg, dtype),
        forward=lambda p, batch, **kw: tf.forward(p, cfg, batch, **kw),
        decode_step=lambda p, tokens, cache: tf.decode_step(p, cfg, tokens, cache),
        init_cache=lambda batch, max_seq, dtype=jnp.bfloat16: tf.init_cache(
            cfg, batch, max_seq, dtype),
        loss=lambda p, batch: tf.lm_loss(p, cfg, batch),
    )
