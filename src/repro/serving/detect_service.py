"""Peregrine control-plane service: the middlebox-server side of the paper.

Consumes packet batches (what the switch would forward), runs the data-plane
feature pipeline, emits per-epoch feature records, and scores them with
KitNET — the full §3.2 workflow as one object.  Tracks the running packet
count so epochs are continuous across batches, and keeps flow-table state
warm between calls (exactly the switch's persistent registers).

Record indices are *global* stream positions (offset by the packet count at
the start of each batch), so a record produced by a streamed run is
attributable to the same packet as in a single-batch run.  The
``observe_stream``/``process_stream`` entry points chunk an arbitrarily long
trace through the service with bounded memory: per-chunk packet arrays plus
the sampled records are all that is ever resident, and MD scoring happens
*per chunk* (per-record scores don't depend on their batch, so chunked
scores/alarms are bit-identical to a one-batch run).

Both compute stages are selectable by name: ``backend=`` picks the FC
implementation (``repro.core.backends`` — e.g. ``backend="bucketed",
buckets=4`` for the mesh-parallel bucketed scans), ``md_backend=`` the
scoring implementation (``repro.detection.md_backends`` — einsum or the
fused Pallas ensemble kernel).

The inference path additionally fuses the whole per-chunk pipeline —
FC → on-device epoch gather → KitNET scoring — into ONE donated jit
(``serving/fused.py``; on by default for exact-mode services, ``fused=``
overrides).  Flow-table state stays resident on device across chunks and
only the sampled ``(indices, scores, alarms)`` ever cross to the host;
``process_stream`` dispatches chunk k+1 before draining chunk k's results,
so the host never serialises on per-chunk transfers.  Donation contract
(DESIGN.md §8): the state handle passed into a fused step is consumed —
snapshot with ``jax.tree_util.tree_map(jnp.copy, svc.state)``, never by
aliasing the tree.

The per-chunk step itself is a SHARED core (``serving/fused._make_core``):
this service jits it one-stream (``make_fused_step``); the multi-tenant
``DetectionEngine`` (serving/engine.py, DESIGN.md §10) vmaps the same core
over a tenant axis — which is why one tenant through the engine reproduces
``process_stream`` bit for bit.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import (compute_features, default_backend, init_state,
                        resolve_backend)
from repro.core.records import epoch_indices
from repro.data.pipeline import phv_batches
from repro.detection.kitnet import KitNet, train_kitnet
from repro.detection.md_backends import (default_md_backend, score_records,
                                         validate_md_options)
from repro.traffic.generator import to_jnp


class DetectionService:
    def __init__(self, epoch: int = 1024, n_slots: int = 8192,
                 mode: str = "exact", threshold: Optional[float] = None,
                 backend: Optional[str] = None,
                 md_backend: Optional[str] = None,
                 md_kw: Optional[Dict] = None,
                 fused: Optional[bool] = None,
                 state_backend: str = "dense",
                 state_kw: Optional[Dict] = None, **backend_kw):
        self.epoch = epoch
        self.mode = mode
        self.backend = resolve_backend(backend if backend is not None
                                       else default_backend(mode))
        self.md_kw = dict(md_kw or {})          # e.g. bb=/interpret= for MD
        # resolves the name AND rejects options the backend doesn't accept
        self.md_backend = validate_md_options(
            md_backend if md_backend is not None else default_md_backend(),
            self.md_kw)
        self.backend_kw = backend_kw            # e.g. shards= for "sharded"
        # fused device-resident inference: default on wherever the exact
        # batch pipeline runs (every backend supports it; the switch
        # approximation mode stays on the staged oracle path)
        self.fused = (mode == "exact") if fused is None else bool(fused)
        # state_backend picks the flow-table layout (dense slots or the
        # Count-Min sketch); state_kw e.g. rows=/evict_age= for "sketch"
        self.state = init_state(n_slots, state_backend=state_backend,
                                **(state_kw or {}))
        self.net: Optional[KitNet] = None
        # thresholds are kept f32-representable so the fused (device, f32)
        # and staged (numpy) comparisons agree bit-for-bit
        self.threshold = (None if threshold is None
                          else float(np.float32(threshold)))
        self.pkt_count = 0
        self._train_feats = []

    # ---- data-plane step (would run on the switch) ----
    def _fc(self, pkts: Dict[str, np.ndarray]) -> np.ndarray:
        pk = to_jnp(pkts)
        self.state, feats = compute_features(self.state, pk,
                                             backend=self.backend,
                                             mode=self.mode,
                                             **self.backend_kw)
        return np.asarray(feats)

    def reset_stream(self, pkt_count: int = 0) -> None:
        """Restart epoch accounting (a new capture); flow tables persist."""
        self.pkt_count = pkt_count

    # ---- training phase ----
    def observe_benign(self, pkts: Dict[str, np.ndarray]) -> np.ndarray:
        """Feed one benign batch; returns the *global* indices of the
        feature records collected for training."""
        feats = self._fc(pkts)
        base = self.pkt_count
        idx = epoch_indices(len(feats), self.epoch, base)
        self.pkt_count += len(feats)
        if len(idx):
            self._train_feats.append(feats[idx])
        return idx + base

    def observe_stream(self, pkts: Dict[str, np.ndarray],
                       chunk: int = 4096) -> np.ndarray:
        """Stream a long benign trace through ``observe_benign`` in
        fixed-size chunks.  Returns all global record indices."""
        out = [self.observe_benign(c) for c in phv_batches(pkts, chunk)]
        return (np.concatenate(out) if out
                else np.zeros((0,), dtype=np.int64))

    def fit(self, seed: int = 0, fpr: float = 0.01) -> None:
        if not self._train_feats:
            raise RuntimeError(
                "no training records collected: observe_benign() never "
                f"crossed an epoch boundary (epoch={self.epoch}, "
                f"{self.pkt_count} packets seen) — feed more benign traffic "
                "or lower `epoch`")
        train = np.concatenate(self._train_feats)
        self.net = train_kitnet(train, seed=seed,
                                md_backend=self.md_backend,
                                md_kw=self.md_kw)
        scores = score_records(self.net, train, backend=self.md_backend,
                               **self.md_kw)
        if self.threshold is None:
            self.threshold = float(np.float32(np.quantile(scores, 1.0 - fpr)))
        self._train_feats = []

    # ---- inference phase ----
    def _fused_step(self):
        from repro.serving.fused import make_fused_step
        return make_fused_step(backend=self.backend, mode=self.mode,
                               backend_kw=self.backend_kw,
                               md_backend=self.md_backend, md_kw=self.md_kw,
                               epoch=self.epoch)

    def _dispatch_fused(self, pkts: Dict[str, np.ndarray]):
        """Launch one fused chunk; returns device futures, does NOT block.

        ``self.state`` is donated to the step and replaced by the returned
        handle — the previous handle is dead from here on (DESIGN.md §8).
        """
        n = len(pkts["ts"])
        base = self.pkt_count
        self.state, idx, scores, alarms, count = self._fused_step()(
            self.state, self.net, np.float32(self.threshold),
            np.int32(base % self.epoch), to_jnp(pkts))
        self.pkt_count += n
        return base, idx, scores, alarms, count

    @staticmethod
    def _drain_fused(out) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Block on one dispatched chunk; only the sampled rows transfer."""
        base, idx, scores, alarms, count = out
        c = int(count)
        return (np.asarray(idx)[:c].astype(np.int64) + base,
                np.asarray(scores)[:c], np.asarray(alarms)[:c])

    def process(self, pkts: Dict[str, np.ndarray],
                fused: Optional[bool] = None
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (global_record_indices, rmse_scores, alarms).

        ``fused=`` overrides the service default: True runs the one-jit
        device-resident step, False the staged FC → numpy sampling → MD
        path.  Outputs are bit-identical between the two for the
        serial-semantics FC backends (tests/test_fused.py).
        """
        assert self.net is not None, "call fit() first"
        if self.fused if fused is None else fused:
            return self._drain_fused(self._dispatch_fused(pkts))
        feats = self._fc(pkts)
        base = self.pkt_count
        idx = epoch_indices(len(feats), self.epoch, base)
        self.pkt_count += len(feats)
        if not len(idx):
            return idx + base, np.zeros((0,)), np.zeros((0,), bool)
        scores = score_records(self.net, feats[idx],
                               backend=self.md_backend, **self.md_kw)
        return idx + base, scores, scores > self.threshold

    def process_stream(self, pkts: Dict[str, np.ndarray], chunk: int = 4096,
                       fused: Optional[bool] = None
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Stream a long trace in fixed-size chunks, carrying flow-table
        state and the running packet count across chunk boundaries.
        Returns concatenated (global_record_indices, scores, alarms) —
        identical to a single ``process`` call on the whole trace for the
        serial-semantics backends (serial/sharded/pallas).

        On the fused path the loop is pipelined: chunk k+1 is dispatched
        to the device *before* chunk k's sampled results are drained to
        the host, so steady-state throughput is bounded by the fused step
        itself, not by per-chunk host synchronisation."""
        use_fused = self.fused if fused is None else fused
        idxs, scores, alarms = [], [], []
        if use_fused:
            assert self.net is not None, "call fit() first"
            pending = None
            for c in phv_batches(pkts, chunk):
                nxt = self._dispatch_fused(c)
                if pending is not None:
                    out = self._drain_fused(pending)
                    for acc, v in zip((idxs, scores, alarms), out):
                        acc.append(v)
                pending = nxt
            if pending is not None:
                out = self._drain_fused(pending)
                for acc, v in zip((idxs, scores, alarms), out):
                    acc.append(v)
        else:
            for c in phv_batches(pkts, chunk):
                i, s, a = self.process(c, fused=False)
                idxs.append(i)
                scores.append(s)
                alarms.append(a)
        if not idxs:
            return (np.zeros((0,), dtype=np.int64), np.zeros((0,)),
                    np.zeros((0,), bool))
        return (np.concatenate(idxs), np.concatenate(scores),
                np.concatenate(alarms))
