"""Forced-multi-device worker for tests/test_mesh.py.

``XLA_FLAGS=--xla_force_host_platform_device_count=N`` is read ONCE, when
jax initialises its backend, so a test session that already imported jax
cannot re-enter a different device topology in-process.  This script is
the escape hatch: ``test_mesh.py`` launches it as a subprocess per device
count —

    python tests/mesh_check.py <n_devices> [battery ...]

— it forces the topology BEFORE importing jax, runs the requested check
batteries (default: all), and prints one ``MESH-OK <battery>`` marker per
battery that passed.  Any assertion failure escapes as a traceback and a
nonzero exit, which the pytest side reports verbatim.

Batteries:

* ``ambient``  — distributed/sharding resolution at N>1: ``flow_mesh``
  binds an N-device mesh, ``ambient_mesh``/``flow_shards_binding``/
  ``tenant_binding`` see it, ``core/bucketed._resolve_placement`` accepts
  it (and falls back when the bucket count does not divide), and the
  placement cache keys (``_shard_ctx`` / fused ``_placement_token``)
  include the device count.
* ``parity``   — bucketed:S features AND final state on the N-device
  ``flow_shards`` mesh match the single-device flat-scan run across all
  attack generators, to the serial-oracle tolerance envelope of
  tests/test_bucketed.py.
* ``fused``    — fused-service stream continuity under the mesh: one-shot
  vs chunked ``process_stream`` under ``flow_mesh(N)``, and both against
  the unplaced single-device run (identical record indices, float-
  tolerance scores).
* ``sketch``   — sketch-backend state under a bound mesh: the Count-Min
  compute path runs unchanged with the mesh rules active (bit-identical
  state and features to the unplaced run).
* ``engine``   — the multi-tenant engine with its tenant axis spread over
  the mesh: per-tenant results match the unplaced engine, and the placed
  tenant step is a distinct compiled executable (cache keyed on
  placement).
"""
import os
import re
import sys

N_DEVICES = int(sys.argv[1]) if len(sys.argv) > 1 else 2
BATTERIES = sys.argv[2:] or ["ambient", "parity", "fused", "sketch",
                             "engine"]

# force the topology before jax initialises; strip any stale force flag
flags = os.environ.get("XLA_FLAGS", "")
flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "", flags)
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={N_DEVICES} " + flags)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (FEATURE_NAMES, N_FEATURES, compute_features,
                        init_state)
from repro.distributed.sharding import (ambient_mesh, flow_mesh,
                                        flow_shards_binding, tenant_binding)
from repro.traffic.generator import ATTACKS, benign_trace

assert jax.device_count() == N_DEVICES, (
    f"forced {N_DEVICES} devices, jax sees {jax.device_count()}")

N_PKTS = 256
N_SLOTS = 512
BUCKETS = 8

_PCC = [i for i, nm in enumerate(FEATURE_NAMES) if nm.endswith(":pcc")]
_NON_PCC = np.setdiff1d(np.arange(N_FEATURES), _PCC)


def _trace(attack, seed=0, n=N_PKTS):
    rng = np.random.default_rng(seed)
    ben = benign_trace(160, 6.0, rng)
    atk = ATTACKS[attack](120, 1.0, 5.0, rng)
    out = {k: np.concatenate([ben[k], atk[k]]) for k in ben}
    order = np.argsort(out["ts"], kind="stable")
    out = {k: v[order][:n] for k, v in out.items()}
    assert len(out["ts"]) == n, attack
    return {k: jnp.asarray(v) for k, v in out.items() if k != "label"}


def _assert_envelope(f, f_ref, tag):
    """The serial-oracle tolerance envelope of tests/test_bucketed.py."""
    ok = np.abs(f - f_ref) <= (1.0 + 1e-3 * np.abs(f_ref))
    assert ok[:, _NON_PCC].all(), (tag, "non-pcc envelope")
    assert ok.mean() >= 0.995, (tag, float(ok.mean()))


def _assert_state(st, st_ref, tag, exact=False):
    for grp in ("uni", "bi"):
        for k in st_ref[grp]:
            a, b = np.asarray(st[grp][k]), np.asarray(st_ref[grp][k])
            if exact or k == "rr":
                np.testing.assert_array_equal(a, b,
                                              err_msg=f"{tag}/{grp}/{k}")
            else:
                np.testing.assert_allclose(a, b, rtol=1e-3, atol=1.0,
                                           err_msg=f"{tag}/{grp}/{k}")


def battery_ambient():
    from repro.core.bucketed import _resolve_placement, _shard_ctx
    from repro.serving.fused import _placement_token

    tok_out = _placement_token()
    assert _resolve_placement(BUCKETS) == (None, None)
    with flow_mesh(N_DEVICES) as mesh:
        m = ambient_mesh()
        assert m is not None and m.devices.size == N_DEVICES, m
        assert flow_shards_binding() == "data"
        assert tenant_binding() == "data"
        rm, rb = _resolve_placement(BUCKETS)
        assert rm is not None and rb == "data", (rm, rb)
        # bucket counts that do not divide over the axis fall back
        assert _resolve_placement(N_DEVICES + 1) == (None, None)
        ctx = _shard_ctx(rm, rb, jax.device_count())
        assert ctx is not None and ctx.size == N_DEVICES
        # one cached context per (mesh, binding, device count)
        assert _shard_ctx(rm, rb, jax.device_count()) is ctx
        tok_in = _placement_token()
        assert tok_in != tok_out
        assert tok_in[-1] == N_DEVICES, tok_in  # device count is in the key
        assert tok_in[2] is not None and tok_in[2] == mesh
    assert _placement_token() == tok_out
    print("MESH-OK ambient")


def battery_parity():
    for attack in sorted(ATTACKS):
        pk = _trace(attack)
        st_ref, f_ref = compute_features(init_state(N_SLOTS), pk,
                                         backend="scan")
        with flow_mesh(N_DEVICES):
            st, f = compute_features(init_state(N_SLOTS), pk,
                                     backend="bucketed", buckets=BUCKETS)
        _assert_envelope(np.asarray(f), np.asarray(f_ref),
                         (attack, N_DEVICES))
        _assert_state(st, st_ref, f"{attack}/N={N_DEVICES}")
    print("MESH-OK parity")


def _fitted_bucketed_service():
    from repro.serving import DetectionService
    from repro.traffic import synth_trace

    data = synth_trace("mirai", n_train=1024, n_benign_eval=512,
                       n_attack=512, seed=0)
    svc = DetectionService(epoch=64, n_slots=N_SLOTS, mode="exact",
                           backend="bucketed", buckets=BUCKETS)
    svc.observe_stream(data["train"], chunk=512)
    svc.fit(fpr=0.05)
    ev = {k: v for k, v in data["eval"].items() if k != "label"}
    return svc, ev


def battery_fused():
    svc, ev = _fitted_bucketed_service()
    snap = jax.tree_util.tree_map(jnp.copy, svc.state)
    c0 = svc.pkt_count
    i_ref, s_ref, _ = svc.process(ev, fused=True)       # unplaced baseline
    svc.state = jax.tree_util.tree_map(jnp.copy, snap)
    svc.pkt_count = c0
    with flow_mesh(N_DEVICES):
        i1, s1, _ = svc.process(ev, fused=True)
    svc.state, svc.pkt_count = snap, c0
    with flow_mesh(N_DEVICES):
        i2, s2, _ = svc.process_stream(ev, chunk=256, fused=True)
    assert len(np.asarray(i_ref)) > 0
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(i_ref), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-5)
    print("MESH-OK fused")


def battery_sketch():
    pk = _trace("mirai")
    st_ref, f_ref = compute_features(
        init_state(N_SLOTS, state_backend="sketch", rows=2), pk)
    with flow_mesh(N_DEVICES):
        st, f = compute_features(
            init_state(N_SLOTS, state_backend="sketch", rows=2), pk)
    np.testing.assert_array_equal(np.asarray(f), np.asarray(f_ref))
    for k, v in st_ref.items():
        if hasattr(v, "shape"):
            np.testing.assert_array_equal(np.asarray(st[k]), np.asarray(v),
                                          err_msg=k)
    print("MESH-OK sketch")


def battery_engine():
    from repro.serving import DetectionEngine
    from repro.serving.fused import make_tenant_step

    svc, ev = _fitted_bucketed_service()

    def run():
        eng = DetectionEngine.from_service(svc, n_tenants=2, chunk=256,
                                           queue_depth=4)
        tids = [eng.add_tenant() for _ in range(2)]
        out = eng.run({t: ev for t in tids})
        eng.close()
        return out

    kw = dict(backend="bucketed", backend_kw={"buckets": BUCKETS},
              epoch=64)
    o_ref = run()
    step_ref = make_tenant_step(**kw)
    with flow_mesh(N_DEVICES):
        o_mesh = run()
        assert make_tenant_step(**kw) is not step_ref
    assert make_tenant_step(**kw) is step_ref
    for t in o_ref:
        idx_r, sc_r, al_r = o_ref[t]
        idx_m, sc_m, al_m = o_mesh[t]
        assert len(idx_r) > 0
        np.testing.assert_array_equal(idx_r, idx_m, err_msg=str(t))
        np.testing.assert_allclose(sc_r, sc_m, rtol=1e-4, atol=1e-6,
                                   err_msg=str(t))
    print("MESH-OK engine")


if __name__ == "__main__":
    for b in BATTERIES:
        globals()[f"battery_{b}"]()
    print("MESH-DONE")
