from repro.distributed.rematctx import (  # noqa: F401
    use_remat, current_remat, maybe_remat,
)
