"""Sharded flow-table FC backend — the switch's partitioned register array.

Peregrine's data plane scales because flow state is a *partitioned* register
array: each pipeline stage owns a disjoint slice of the slot space and
packets are routed to the owning partition by their slot index.  This module
reproduces that layout in JAX: the flow tables are hash-partitioned into S
shards (shard = slot mod S, local row = slot div S), every shard runs the
serial oracle's per-packet update on its own slice, and the shards execute
in parallel — ``vmap`` over the shard axis on one device, and placed across
a mesh via the ``flow_shards`` logical axis (distributed/sharding.py) when
one is bound.

Exactness: slots never interact, so any partition that preserves each slot's
packet order is *bit-identical* to the serial oracle.  Each shard scans the
full packet batch; packets whose slot (per key type) lives elsewhere are
redirected to a scratch row that is dropped on un-sharding, and the (n, 80)
feature matrix is assembled by selecting each key-type block from its owning
shard.  Both ``exact`` and ``switch`` arithmetic modes are supported — the
round-robin counters are per-slot state, so they shard like everything else.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.pipeline import _packet_step
from repro.core.state import (
    BI_KEYS, N_FEATURES, UNI_KEYS, packet_slots, state_slots,
)
from repro.distributed.sharding import current_rules

# feature-column block owned by each key type (oracle layout: uni blocks of
# N_DECAY*3, then bi blocks of N_DECAY*7)
_BLOCKS = (("src_mac_ip", 0, 12), ("src_ip", 12, 24),
           ("channel", 24, 52), ("socket", 52, 80))
assert _BLOCKS[-1][2] == N_FEATURES

# table leaves that mean "never seen" at -1 (scratch rows start fresh)
_FRESH_AT_MINUS1 = ("last_t", "sr_last_t")


def shard_tables(state: Dict, shards: int) -> Dict:
    """Global tables -> per-shard slices + one scratch row per shard.

    Leaf (K, n_slots, ...) -> (S, K, n_slots//S + 1, ...); global slot g
    lives in shard ``g % S`` at local row ``g // S``; local row n_local is
    the scratch row non-member packets are redirected to.
    """
    def leaf(x, fill):
        k, ns = x.shape[0], x.shape[1]
        nl = ns // shards
        y = jnp.moveaxis(x.reshape(k, nl, shards, *x.shape[2:]), 2, 0)
        pad = jnp.full((shards, k, 1) + x.shape[2:], fill, x.dtype)
        return jnp.concatenate([y, pad], axis=2)

    return {grp: {f: leaf(v, -1.0 if f in _FRESH_AT_MINUS1 else 0)
                  for f, v in state[grp].items()}
            for grp in ("uni", "bi")}


def unshard_tables(sharded: Dict, shards: int) -> Dict:
    """Inverse of ``shard_tables`` (scratch rows dropped)."""
    def leaf(y):
        y = y[:, :, :-1]
        k, nl = y.shape[1], y.shape[2]
        return jnp.moveaxis(y, 0, 2).reshape(k, nl * shards, *y.shape[3:])

    return {grp: {f: leaf(v) for f, v in sharded[grp].items()}
            for grp in ("uni", "bi")}


def _constrain_shards(tree, binding):
    """Place the leading shard axis on the mesh via the ``flow_shards``
    logical-axis ``binding``.  No-op when unbound (single-device)."""
    if binding is None:
        return tree

    def c(x):
        return jax.lax.with_sharding_constraint(
            x, P(binding, *([None] * (x.ndim - 1))))

    return jax.tree_util.tree_map(c, tree)


def process_sharded(state: Dict, pkts: Dict[str, jax.Array],
                    shards: int = 4, mode: str = "exact"
                    ) -> Tuple[Dict, jax.Array]:
    """Hash-partitioned FC: same I/O as ``process_serial``, bit-identical
    features/state, shards executed in parallel over a vmapped shard axis.

    The ambient ``flow_shards`` rule binding is resolved *here*, outside
    jit, and passed down as a static argument — it participates in the jit
    cache key, so toggling ``use_rules`` retraces instead of silently
    reusing an executable compiled under a different placement.
    """
    rules = current_rules()
    binding = rules.rules.get("flow_shards") if rules is not None else None
    if isinstance(binding, list):
        binding = tuple(binding)
    return _process_sharded(state, pkts, shards=shards, mode=mode,
                            flow_binding=binding)


@partial(jax.jit, static_argnames=("shards", "mode", "flow_binding"))
def _process_sharded(state: Dict, pkts: Dict[str, jax.Array],
                     shards: int, mode: str, flow_binding
                     ) -> Tuple[Dict, jax.Array]:
    n_slots = state_slots(state)
    if n_slots % shards:
        raise ValueError(
            f"n_slots={n_slots} not divisible by shards={shards}; "
            "flow tables partition the slot space evenly")
    n_local = n_slots // shards
    sl = packet_slots(pkts, n_slots)
    ts = pkts["ts"].astype(jnp.float32)
    lens = pkts["length"].astype(jnp.float32)
    n = ts.shape[0]

    # route each packet (per key type) to its shard's local row; non-member
    # packets go to the scratch row n_local
    sid = jnp.arange(shards, dtype=jnp.int32)[:, None]          # (S, 1)
    routed = {k: jnp.where(sl[k][None] % shards == sid,
                           sl[k][None] // shards, n_local).astype(jnp.int32)
              for k in UNI_KEYS + BI_KEYS}                      # each (S, n)

    tables = _constrain_shards(shard_tables(state, shards), flow_binding)
    routed = _constrain_shards(routed, flow_binding)

    def run_shard(tab, routes):
        xs = {"ts": ts, "length": lens, "dir": sl["dir"], **routes}

        def step(tb, x):
            st, f = _packet_step(tb, x, mode)
            return {g: st[g] for g in ("uni", "bi")}, f

        return jax.lax.scan(step, tab, xs)

    tables, feats_all = jax.vmap(run_shard)(tables, routed)     # (S, n, 80)

    # assemble features: each key-type block comes from its owning shard
    rows = jnp.arange(n)
    feats = jnp.concatenate(
        [feats_all[sl[key] % shards, rows, a:b] for key, a, b in _BLOCKS],
        axis=-1)
    return unshard_tables(tables, shards), feats
