"""Training substrate: convergence, microbatch equivalence, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_arch, reduced
from repro.data import lm_batches
from repro.models import build_model
from repro.training import CheckpointManager, init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _model():
    return build_model(reduced(get_arch("deepseek-7b")))


def _jbatch(b):
    return {k: jnp.asarray(v) for k, v in b.items()}


def test_loss_decreases():
    m = _model()
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=2)
    state = init_train_state(m, tc, KEY)
    step = jax.jit(make_train_step(m, tc))
    losses = []
    for b in lm_batches(m.cfg.vocab, 8, 32, 20, seed=1):
        state, metrics = step(state, _jbatch(b))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[:3] + losses[-3:]


def test_microbatch_equivalence():
    m = _model()
    batches = [next(iter(lm_batches(m.cfg.vocab, 8, 16, 1, seed=2)))]
    outs = {}
    for mb in (1, 4):
        tc = TrainConfig(microbatches=mb)
        state = init_train_state(m, tc, KEY)
        step = jax.jit(make_train_step(m, tc))
        state, metrics = step(state, _jbatch(batches[0]))
        outs[mb] = (float(metrics["loss"]),
                    np.asarray(jax.tree_util.tree_leaves(
                        state["params"])[0]))
    assert abs(outs[1][0] - outs[4][0]) < 1e-3
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=1e-3, atol=1e-5)


def test_remat_matches_no_remat():
    m = _model()
    b = _jbatch(next(iter(lm_batches(m.cfg.vocab, 4, 16, 1, seed=3))))
    results = {}
    for remat in ("none", "dots", "full"):
        tc = TrainConfig(remat=remat)
        state = init_train_state(m, tc, KEY)
        step = jax.jit(make_train_step(m, tc))
        _, metrics = step(state, b)
        results[remat] = float(metrics["loss"])
    assert abs(results["none"] - results["dots"]) < 1e-5
    assert abs(results["none"] - results["full"]) < 1e-5


def test_grad_compression_converges():
    m = _model()
    tc = TrainConfig(learning_rate=1e-3, grad_compression="int8_ef",
                     warmup_steps=2)
    state = init_train_state(m, tc, KEY)
    step = jax.jit(make_train_step(m, tc))
    losses = []
    for b in lm_batches(m.cfg.vocab, 8, 32, 15, seed=1):
        state, metrics = step(state, _jbatch(b))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.15


def test_checkpoint_roundtrip(tmp_path):
    m = _model()
    tc = TrainConfig()
    state = init_train_state(m, tc, KEY)
    step = jax.jit(make_train_step(m, tc))
    b = _jbatch(next(iter(lm_batches(m.cfg.vocab, 4, 16, 1))))
    state, _ = step(state, b)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, state)
    restored, rstep = mgr.restore(jax.eval_shape(lambda: state))
    assert rstep == 1
    for a, c in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_checkpoint_gc_and_latest(tmp_path):
    m = _model()
    tc = TrainConfig()
    state = init_train_state(m, tc, KEY)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    m = _model()
    tc = TrainConfig()
    state = init_train_state(m, tc, KEY)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(7, state)
    mgr.wait()
    assert mgr.latest_step() == 7


def test_checkpoint_ignores_uncommitted(tmp_path):
    m = _model()
    tc = TrainConfig()
    state = init_train_state(m, tc, KEY)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state)
    # simulate a torn write: step_2 without COMMIT
    import shutil
    shutil.copytree(os.path.join(tmp_path, "step_1"),
                    os.path.join(tmp_path, "step_2"))
    os.remove(os.path.join(tmp_path, "step_2", "COMMIT"))
    assert mgr.latest_step() == 1
