"""Serve a small LM with batched requests through the continuous-batching
engine (the decode path the decode_32k dry-run cells lower).

  PYTHONPATH=src python examples/serve_lm.py --requests 6 --slots 3
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.models.lm_engine import Request, ServeEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma2-2b")
ap.add_argument("--slots", type=int, default=3)
ap.add_argument("--requests", type=int, default=6)
ap.add_argument("--max-new", type=int, default=12)
args = ap.parse_args()

cfg = reduced(get_arch(args.arch))
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
engine = ServeEngine(model, params, batch_slots=args.slots, max_seq=128)

rng = np.random.default_rng(0)
for rid in range(args.requests):
    engine.submit(Request(
        rid=rid,
        prompt=jnp.asarray(rng.integers(1, cfg.vocab, 16), jnp.int32),
        max_new=args.max_new))

t0 = time.time()
outputs = engine.run()
dt = time.time() - t0
total = sum(len(v) for v in outputs.values())
for rid in sorted(outputs):
    print(f"request {rid}: {outputs[rid]}")
print(f"{len(outputs)} requests, {total} tokens, {total / dt:.1f} tok/s "
      f"(CPU, {args.slots} slots)")
