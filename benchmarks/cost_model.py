"""Figures 11-12: monetary cost & power of scaling detection to Terabit line
rates — server fleet vs Peregrine's switch+server split.

Quantitative model mirroring §5.7 (constants from the cited literature /
public list prices; worst-case switch power as the paper does):
  * middlebox detector capacity: measured MD throughput mapped to the
    paper's ~15 Gbps per-server ceiling (Whisper-class, kernel-bypass)
  * server: $6,000, 500 W (dual-Xeon + 100G NIC, as §5.1's testbed)
  * Tofino switch: $10,000, 450 W worst case — constant, line-rate FC
  * Peregrine still needs ONE detection server per deployment (record
    stream at 1:32768 fits a single box, §5.5)
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save

SERVER_COST, SERVER_W = 6000.0, 500.0
SWITCH_COST, SWITCH_W = 10000.0, 450.0
SERVER_GBPS = 15.0          # per-server detection ceiling (Whisper-class)

LINE_RATES = (100, 400, 800, 1600, 3200, 6400)   # Gbps


def main():
    rows = []
    for g in LINE_RATES:
        n_srv = int(np.ceil(g / SERVER_GBPS))
        fleet = {"servers": n_srv, "cost": n_srv * SERVER_COST,
                 "power_w": n_srv * SERVER_W}
        pereg = {"servers": 1, "cost": SWITCH_COST + SERVER_COST,
                 "power_w": SWITCH_W + SERVER_W}
        rows.append({"line_rate_gbps": g, "fleet": fleet, "peregrine": pereg,
                     "cost_ratio": fleet["cost"] / pereg["cost"],
                     "power_ratio": fleet["power_w"] / pereg["power_w"]})
        print(f"{g:5d} Gbps  fleet: {n_srv:4d} srv ${fleet['cost']:9,.0f} "
              f"{fleet['power_w'] / 1000:7.1f} kW | peregrine: "
              f"${pereg['cost']:7,.0f} {pereg['power_w'] / 1000:4.2f} kW "
              f"| {rows[-1]['cost_ratio']:5.1f}x cost {rows[-1]['power_ratio']:5.1f}x power")
    save("cost_model", {"rows": rows, "constants": {
        "server_cost": SERVER_COST, "server_w": SERVER_W,
        "switch_cost": SWITCH_COST, "switch_w": SWITCH_W,
        "server_gbps": SERVER_GBPS}})


if __name__ == "__main__":
    main()
