"""Approximate-arithmetic model of the Tofino data plane (§4 of the paper).

The switch has no multiply/divide/sqrt. Peregrine approximates:
  * mul/div      -> round one operand to the nearest power of two, then shift
                    (ternary-match tables select the shift amount);
  * sqrt/square  -> Tofino "math unit": a 16-entry lookup on the operand's
                    top mantissa bits + exponent scaling (low-precision).

We reproduce those *semantics* in vectorised jnp so the detection-performance
claims (incl. the approximation-as-regularizer conjecture, §5.4) can be
evaluated; ``mode="exact"`` bypasses all of it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def invert_perm(order: jax.Array) -> jax.Array:
    """Inverse of a permutation: ``invert_perm(order)[order[i]] == i``.

    The scatter form (`zeros.at[order].set(arange)`) is O(n) — cheaper than
    a second argsort — and is the canonical way every sorted-order pass in
    ``core/parallel.py`` maps results back to original packet order.
    """
    return jnp.zeros_like(order).at[order].set(
        jnp.arange(order.shape[0], dtype=order.dtype))


def _ilog2(x: jax.Array) -> jax.Array:
    """floor(log2 x) for x>0 (f32), elementwise."""
    return jnp.floor(jnp.log2(jnp.maximum(x, _EPS)))


def shift_div(a: jax.Array, b: jax.Array) -> jax.Array:
    """a / b with b rounded to the nearest upper power of two (right shift).

    Integer semantics: the switch divides 32-bit counters; a divisor that
    truncates to 0 yields 0, and the shifted result is floored.
    """
    e = jnp.ceil(jnp.log2(jnp.maximum(b, _EPS)))
    out = jnp.floor(a * jnp.exp2(-e))
    return jnp.where(b >= 1.0, out, 0.0)


def shift_mul(a: jax.Array, b: jax.Array) -> jax.Array:
    """a * b with b rounded to the nearest power of two (left shift)."""
    e = jnp.round(jnp.log2(jnp.maximum(b, _EPS)))
    out = jnp.floor(a * jnp.exp2(e))
    return jnp.where(b >= 1.0, out, 0.0)


# --- Tofino math-unit model: 16-entry LUT over mantissa, exponent rescale ---
_LUT_N = 16


def _mathunit(x: jax.Array, fn) -> jax.Array:
    """Apply fn via exponent/mantissa decomposition with a 16-entry LUT.

    x = m * 2^e with m in [1, 2); LUT indexes floor((m-1)*16).
    Result = fn(lut_m) * fn(2^e) — an 8-bit-precision approximation like the
    TNA math unit.
    """
    x = jnp.maximum(x, 0.0)
    e = _ilog2(jnp.maximum(x, _EPS))
    m = x * jnp.exp2(-e)                            # [1, 2)
    idx = jnp.clip((m - 1.0) * _LUT_N, 0, _LUT_N - 1).astype(jnp.int32)
    centers = 1.0 + (jnp.arange(_LUT_N, dtype=jnp.float32) + 0.5) / _LUT_N
    lut = fn(centers)
    out = jnp.floor(lut[idx] * fn(jnp.exp2(e)))
    return jnp.where(x >= 1.0, out, 0.0)


def mathunit_sqrt(x: jax.Array) -> jax.Array:
    # fn(2^e) must be exact for the exponent part: sqrt(2^e) = 2^(e/2)
    x = jnp.maximum(x, 0.0)
    e = _ilog2(jnp.maximum(x, _EPS))
    e_even = 2.0 * jnp.floor(e / 2.0)               # even exponent split
    m = x * jnp.exp2(-e_even)                       # [1, 4)
    idx = jnp.clip((m - 1.0) / 3.0 * _LUT_N, 0, _LUT_N - 1).astype(jnp.int32)
    centers = 1.0 + (jnp.arange(_LUT_N, dtype=jnp.float32) + 0.5) * (3.0 / _LUT_N)
    lut = jnp.sqrt(centers)
    out = jnp.floor(lut[idx] * jnp.exp2(e_even / 2.0))
    return jnp.where(x >= 1.0, out, 0.0)


def mathunit_square(x: jax.Array) -> jax.Array:
    return _mathunit(x, lambda v: v * v)


def quantized_decay(lam: float, dt: jax.Array) -> jax.Array:
    """Switch decay: 2^(-floor(lam*dt)) — iterated halvings (right shifts).

    dt below the decay window (lam*dt < 1) applies no decay, matching the
    interval check in §4 ("Handling Multiple Decay Factors").
    """
    k = jnp.clip(jnp.floor(lam * jnp.maximum(dt, 0.0)), 0.0, 31.0)
    return jnp.exp2(-k)


def exact_decay(lam: float, dt: jax.Array) -> jax.Array:
    """delta = 2^(-lam*dt)  (Equation 1)."""
    return jnp.exp2(-lam * jnp.maximum(dt, 0.0))


def div(a, b, mode: str):
    if mode == "switch":
        return shift_div(a, b)
    return jnp.where(b > 0, a / jnp.maximum(b, _EPS), 0.0)


def sqrt(x, mode: str):
    if mode == "switch":
        return mathunit_sqrt(x)
    return jnp.sqrt(jnp.maximum(x, 0.0))


def square(x, mode: str):
    if mode == "switch":
        return mathunit_square(x)
    return x * x


def decay(lam: float, dt: jax.Array, mode: str):
    if mode == "switch":
        return quantized_decay(lam, dt)
    return exact_decay(lam, dt)
