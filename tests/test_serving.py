"""LM serving-engine tests (``repro.models.lm_engine`` — the seed's LM
scaffolding, moved out of ``repro.serving``, which now hosts the Peregrine
detection engine; see tests/test_engine.py for that)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import build_model
from repro.models.lm_engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


def test_engine_matches_manual_decode():
    cfg = reduced(get_arch("deepseek-7b"))
    model = build_model(cfg)
    params = model.init_params(KEY)
    prompt = jax.random.randint(KEY, (12,), 1, cfg.vocab)

    eng = ServeEngine(model, params, batch_slots=1, max_seq=64)

    # manual greedy decode using the SAME jitted step the engine uses
    # (jit/nojit argmax near-ties differ on an untrained model)
    logits, _, cache = model.forward(params, {"tokens": prompt[None]},
                                     build_cache=True, max_seq=64)
    toks = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(5):
        lg, cache = eng._decode(
            params, jnp.asarray([[toks[-1]]], jnp.int32), cache)
        toks.append(int(jnp.argmax(lg[0, 0])))

    eng.submit(Request(rid=0, prompt=prompt, max_new=6))
    out = eng.run()
    assert out[0] == toks, (out[0], toks)


def test_engine_multi_slot_throughput():
    cfg = reduced(get_arch("gemma2-2b"))
    model = build_model(cfg)
    params = model.init_params(KEY)
    eng = ServeEngine(model, params, batch_slots=3, max_seq=64)
    rng = np.random.default_rng(0)
    for rid in range(5):
        eng.submit(Request(
            rid=rid,
            prompt=jnp.asarray(rng.integers(1, cfg.vocab, 8), jnp.int32),
            max_new=4))
    out = eng.run()
    assert len(out) == 5
    assert all(len(v) == 4 for v in out.values())
