"""KitNET + end-to-end detection behaviour (small traces)."""
import numpy as np
import pytest

from repro.detection.kitnet import feature_map, train_kitnet, score_kitnet
from repro.detection.metrics import auc, f1_at_fpr, threshold_at_fpr
from repro.serving import DetectionService
from repro.traffic import synth_trace, ATTACKS, benign_trace


def test_feature_map_cluster_sizes():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(500, 40))
    X[:, 10:20] = X[:, 0:10] * 2 + rng.normal(scale=0.01, size=(500, 10))
    clusters = feature_map(X, max_size=10)
    assert all(len(c) <= 10 for c in clusters)
    assert sorted(np.concatenate(clusters).tolist()) == list(range(40))


def test_feature_map_degenerate_inputs():
    """Regression: <2 features and NaN correlation distances must not crash
    scipy's linkage (empty condensed matrix / NaN propagation)."""
    rng = np.random.default_rng(0)
    # single feature: one cluster, no condensed distance to build
    one = feature_map(rng.normal(size=(50, 1)))
    assert [c.tolist() for c in one] == [[0]]
    # zero features: no clusters
    assert feature_map(np.zeros((10, 0))) == []
    # constant-feature trace: zero std columns, distances stay finite
    clusters = feature_map(np.ones((40, 6), np.float32), max_size=4)
    assert sorted(np.concatenate(clusters).tolist()) == list(range(6))
    assert all(len(c) <= 4 for c in clusters)
    # non-finite column (flood-style feature overflow) -> NaN distances
    X = rng.normal(size=(30, 4))
    X[:, 1] = np.inf
    clusters = feature_map(X)
    assert sorted(np.concatenate(clusters).tolist()) == list(range(4))
    # zero-record trace
    clusters = feature_map(np.zeros((0, 5)))
    assert sorted(np.concatenate(clusters).tolist()) == list(range(5))


def test_fit_without_records_raises():
    """Regression: short trace + large epoch used to crash np.concatenate
    with a bare ValueError; now a clear error explains the fix."""
    svc = DetectionService(epoch=10_000, n_slots=256)
    svc.observe_benign(benign_trace(500, 2.0, np.random.default_rng(0)))
    with pytest.raises(RuntimeError, match="no training records"):
        svc.fit()


def test_kitnet_scores_anomalies_higher():
    rng = np.random.default_rng(1)
    train = rng.normal(size=(2000, 30)).astype(np.float32)
    net = train_kitnet(train, seed=0)
    benign = rng.normal(size=(200, 30)).astype(np.float32)
    anom = benign + 6.0      # large distribution shift
    s_b = score_kitnet(net, benign)
    s_a = score_kitnet(net, anom)
    assert np.median(s_a) > np.median(s_b) * 1.5
    labels = np.r_[np.zeros(200), np.ones(200)]
    assert auc(np.r_[s_b, s_a], labels) > 0.95


def test_metrics_sanity():
    scores = np.r_[np.zeros(90), np.ones(10)]
    labels = np.r_[np.zeros(90), np.ones(10)]
    assert auc(scores, labels) == 1.0
    thr = threshold_at_fpr(scores[:90], 0.01)
    assert thr >= 0.0
    assert f1_at_fpr(scores, labels, 0.1) > 0.9


def test_all_attack_generators_produce_valid_traces():
    rng = np.random.default_rng(0)
    for name in ATTACKS:
        tr = ATTACKS[name](500, 0.0, 10.0, rng)
        n = len(tr["ts"])
        assert 0 < n <= 520, name
        assert (np.diff(tr["ts"]) >= 0).all(), name
        assert (tr["label"] == 1).all(), name
        assert tr["length"].min() >= 40 and tr["length"].max() <= 1600, name


def test_benign_trace_sorted_and_sized():
    rng = np.random.default_rng(0)
    tr = benign_trace(3000, 10.0, rng)
    assert len(tr["ts"]) == 3000
    assert (np.diff(tr["ts"]) >= 0).all()
    assert (tr["label"] == 0).all()


def test_detection_service_end_to_end():
    data = synth_trace("syn_dos", n_train=4000, n_benign_eval=3000,
                       n_attack=3000, seed=2)
    svc = DetectionService(epoch=64, n_slots=4096, mode="exact")
    tr_idx = svc.observe_benign(data["train"])
    # record indices are global stream positions
    assert list(tr_idx[:2]) == [63, 127] and svc.pkt_count == 4000
    svc.fit(fpr=0.05)
    eval_start = svc.pkt_count
    idx, scores, alarms = svc.process(data["eval"])
    assert (idx >= eval_start).all()
    labels = data["eval"]["label"][idx - eval_start]
    a = auc(scores, labels)
    assert a > 0.85, a
    # alarms should be dominated by attack records at this threshold
    if alarms.sum() > 0:
        precision = labels[alarms].mean()
        assert precision > 0.7


def test_streamed_chunks_match_single_batch():
    """Continuity across chunk boundaries: one big batch and many small
    chunks must produce identical global record indices, scores, and alarms
    (serial-semantics backend -> features are bit-identical)."""
    import jax

    data = synth_trace("mirai", n_train=1024, n_benign_eval=512,
                       n_attack=512, seed=4)
    svc = DetectionService(epoch=64, n_slots=1024, mode="exact",
                           backend="sharded", shards=4)
    svc.observe_stream(data["train"], chunk=256)
    svc.fit(fpr=0.05)
    snap_state = jax.tree_util.tree_map(jax.numpy.copy, svc.state)  # fused steps donate
    snap_count = svc.pkt_count

    idx1, s1, a1 = svc.process(data["eval"])
    svc.state, svc.pkt_count = snap_state, snap_count
    # uneven chunking so epoch boundaries straddle chunk boundaries
    idx2, s2, a2 = svc.process_stream(data["eval"], chunk=200)

    np.testing.assert_array_equal(idx1, idx2)
    np.testing.assert_array_equal(s1, s2)
    np.testing.assert_array_equal(a1, a2)
    # training-side continuity: chunked observe == one-shot observe
    svc_one = DetectionService(epoch=64, n_slots=1024, mode="exact",
                               backend="sharded", shards=4)
    tr_one = svc_one.observe_benign(data["train"])
    svc_chunks = DetectionService(epoch=64, n_slots=1024, mode="exact",
                                  backend="sharded", shards=4)
    tr_str = svc_chunks.observe_stream(data["train"], chunk=200)
    np.testing.assert_array_equal(tr_one, tr_str)
    np.testing.assert_array_equal(np.concatenate(svc_one._train_feats),
                                  np.concatenate(svc_chunks._train_feats))


def test_peregrine_beats_kitsune_under_sampling():
    """The paper's core claim on one attack at an aggressive rate."""
    from repro.detection.sweep import sweep_attack
    data = synth_trace("syn_dos", n_train=8000, n_benign_eval=6000,
                       n_attack=6000, seed=3)
    res = sweep_attack(data, rates=[256], mode="exact")
    p = res["peregrine"][256]["auc"]
    k = res["kitsune"][256]["auc"]
    assert p > 0.9, res
    assert p >= k - 0.01, res   # baseline never beats Peregrine here
