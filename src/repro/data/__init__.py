from repro.data.pipeline import lm_batches, Prefetcher, phv_batches  # noqa: F401
