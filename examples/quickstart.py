"""Quickstart: the whole Peregrine loop in ~40 lines.

Synthesises a Mirai-style trace, trains the detector on the benign prefix,
then streams the attack window through the data-plane feature pipeline and
scores per-epoch records — §3.2's workflow end to end.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.detection.metrics import auc
from repro.data import phv_batches
from repro.serving import DetectionService
from repro.traffic import synth_trace

# 1. a trace: benign training prefix + eval window with the attack mixed in
data = synth_trace("mirai", n_train=12000, n_benign_eval=6000,
                   n_attack=6000, seed=0)

# 2. the detector: per-packet FC in the (TPU) data plane, one feature record
#    every 256 packets to the KitNET classifier — sampling AFTER features.
svc = DetectionService(epoch=256, n_slots=8192, mode="exact")

# 3. training phase: benign traffic only (first 1M packets in the paper)
for chunk in phv_batches(data["train"], 4096):
    svc.observe_benign(chunk)
svc.fit(fpr=0.01)
print(f"trained; alarm threshold RMSE={svc.threshold:.4f}")

# 4. detection phase: stream the eval window
scores, labels, alarms = [], [], 0
for chunk in phv_batches(data["eval"], 4096):
    idx, s, al = svc.process(chunk)
    scores.append(s)
    labels.append(chunk["label"][idx])
    alarms += int(al.sum())

scores = np.concatenate(scores)
labels = np.concatenate(labels)
print(f"{len(scores)} records scored, {alarms} alarms")
print(f"attack-record AUC = {auc(scores, labels):.3f}  "
      f"(paper: >0.8 for 13/15 attacks)")
