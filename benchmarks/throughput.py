"""Figure 8 analog: system throughput vs sampling rate.

The paper measures 100G-link packet rates against the ML classifier's
record-processing rate, binary-searching the highest stable rate.  Offline
(CPU-only) we measure the two component rates directly and derive the same
curve:

    stable_pps(rate) = min(FC_pps, MD_records_per_s * rate)

FC_pps is measured per backend through the unified
``repro.core.backends.compute_features`` API — any registered backend can be
benchmarked by name (``--backends serial,scan,pallas``):

  * serial — per-packet switch-semantics oracle (lax.scan);
  * scan   — TPU-native segmented-scan pipeline;
  * pallas — the full-feature Pallas kernel (interpret mode on CPU; on TPU
    this is the line-rate path).

The TPU projection for the scan pipeline is derived from its roofline bytes
(see EXPERIMENTS.md §Perf — Peregrine pipeline).
"""
from __future__ import annotations

import argparse

import jax

from benchmarks.common import save, timeit
from repro.core import (available_backends, compute_features, init_state,
                        resolve_backend)
from repro.detection.kitnet import score_kitnet, train_kitnet
from repro.traffic import synth_trace, to_jnp

import numpy as np

# the serial oracle is orders of magnitude slower per packet: measure it on
# a truncated stream so the benchmark finishes
_BACKEND_PKTS = {"serial": 2000, "scan": None, "pallas": 4096}


def fc_rates(n_pkts: int = 20000, n_slots: int = 8192,
             backends=("serial", "scan", "pallas")):
    data = synth_trace("mirai", n_train=n_pkts, n_benign_eval=1000,
                       n_attack=1000, seed=0)
    pk = to_jnp(data["train"])
    st = init_state(n_slots)

    out = {}
    for name in backends:
        name = resolve_backend(name)    # alias-proof cap/mode selection
        cap = _BACKEND_PKTS.get(name)
        n = n_pkts if cap is None else min(cap, n_pkts)
        pk_n = {k: v[:n] for k, v in pk.items()}
        mode = "switch" if name == "serial" else "exact"
        reps = 3 if name == "scan" else 1
        t = timeit(lambda: jax.block_until_ready(compute_features(
            st, pk_n, backend=name, mode=mode)[1]), reps=reps)
        out[f"{name}_pps"] = n / t
    return out


def md_rate(n_train: int = 4000, n_score: int = 8192):
    rng = np.random.default_rng(0)
    feats = rng.random((n_train, 80)).astype(np.float32)
    net = train_kitnet(feats, seed=0)
    batch = rng.random((n_score, 80)).astype(np.float32)
    t = timeit(lambda: score_kitnet(net, batch), reps=3)
    return n_score / t


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backends", default="serial,scan,pallas",
                    help=f"comma list from {available_backends()}")
    args = ap.parse_args()
    n = 8000 if args.quick else 40000
    backends = tuple(b.strip() for b in args.backends.split(",") if b.strip())
    fc = fc_rates(n_pkts=n, backends=backends)
    md = md_rate()
    rates = (1, 64, 1024, 32768)
    # Fig8 pins the curve to the deployable batch pipeline (scan); other
    # backends are component diagnostics, not FC deployment rates
    curve_fc = fc.get("scan_pps", max(fc.values()))
    curve = {r: min(curve_fc, md * r) for r in rates}
    out = {**fc, "md_records_per_s": md,
           "stable_pps_at_rate": curve,
           "note": "on-CPU single-core; Fig8 shape: throughput rises with "
                   "sampling rate until FC-bound"}
    for k, v in out.items():
        if isinstance(v, float):
            print(f"{k:26s} {v:12.0f}")
    print("stable pps:", {r: int(v) for r, v in curve.items()})
    save("throughput", out)


if __name__ == "__main__":
    main()
