"""Logical-axis sharding: models annotate activations/params with *logical*
axis names; launch code binds them to physical mesh axes.

No mesh bound (tests, single-device smoke) -> every annotation is a no-op,
so the exact same model code runs on 1 CPU device and on a 512-chip mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

# Default production rules: batch over (pod, data); model-parallel dims over
# model; experts over model (EP); sequence sharding (decode long-context KV)
# over data.
PRODUCTION_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "experts": "model",
    "expert_cap": ("pod", "data"),
    "vocab": "model",
    "embed": None,
    "seq": None,
    "kv_seq": None,          # overridden to ("pod", "data") for long-context
    "ssm_inner": "model",
    "opt": ("pod", "data"),  # ZeRO-1 optimizer-state axis
    # Peregrine flow-table partitions (core/sharded.py): the shard axis of
    # the hash-partitioned flow state spreads over the DP axes
    "flow_shards": ("pod", "data"),
    # Peregrine multi-tenant engine (serving/engine.py): the tenant lanes of
    # the tenant-batched fused step spread over the DP axes
    "tenants": ("pod", "data"),
}


def set_mesh(mesh):
    """``jax.set_mesh`` across jax versions.

    Newer jax exposes ``jax.set_mesh`` as the context manager binding the
    ambient mesh; on older releases (<= 0.4.x) ``jax.sharding.Mesh`` itself
    is the context manager providing the resource environment that lets
    ``jax.jit`` resolve bare PartitionSpecs.  Call sites use this shim so
    the tier-1 suite runs on both.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def ambient_mesh():
    """The physical mesh bound by :func:`set_mesh`, or ``None``.

    Works across jax versions: newer releases track the ambient mesh on the
    jax side (``jax.set_mesh``), older ones (<= 0.4.x) stash the ``with
    Mesh(...)`` resource environment in ``thread_resources``.  Callers that
    need an explicit ``Mesh`` object (e.g. ``shard_map`` in
    ``core/bucketed.py``) use this instead of threading one by hand.
    """
    try:
        m = jax.interpreters.pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:  # newer jax: the ambient concrete mesh, when one is set
        m = jax.sharding.get_mesh()
        if m is not None and getattr(m, "axis_names", ()):
            return m
    except Exception:
        pass
    return None


def _rule_binding(name: str):
    rules = current_rules()
    binding = rules.rules.get(name) if rules is not None else None
    if isinstance(binding, list):
        binding = tuple(binding)
    return binding


def flow_shards_binding():
    """The normalised ``flow_shards`` rule of the ambient axis rules, or
    ``None`` when unbound.  Shared by everything that keys compiled
    executables on the flow-table placement (``core/bucketed.py``'s
    trace-time resolution and ``serving/fused.py``'s step-cache key), so
    the two can never drift apart."""
    return _rule_binding("flow_shards")


def tenant_binding():
    """The normalised ``tenants`` rule — the mesh axis (or axes) the
    multi-tenant engine's lane dimension spreads over — or ``None`` when
    unbound.  Consumed by ``serving/fused.make_tenant_step`` both for the
    lane sharding constraint and for its step-cache key."""
    return _rule_binding("tenants")


class ShardContext:
    """Resolved mesh placement for the two-level bucketed scans.

    ``core/parallel.py``'s segmented-scan helpers take one of these (built
    by ``core/bucketed.py`` from the ambient mesh + ``flow_shards`` rule)
    and keep EVERY O(n) step of the chunked scan shard-local: the local
    per-chunk scans, the carry fix-up, and the where-selects all run inside
    one ``shard_map`` region whose only collective is ``gather_tails`` —
    an all-gather of the O(S) per-chunk tail summaries (a few KB), never a
    full-batch transfer.

    Instances are built once per (mesh, binding, device count) and cached
    (``core/bucketed._shard_ctx``) so they are stable jit-cache keys.
    """

    def __init__(self, mesh, binding):
        self.mesh = mesh
        self.binding = binding
        self.axes: Tuple[str, ...] = (binding if isinstance(binding, tuple)
                                      else (binding,))
        size = 1
        for a in self.axes:
            size *= mesh.shape[a]
        self.size = size

    def wrap(self, fn):
        """Run ``fn`` under ``shard_map`` with every input/output's leading
        (chunk) axis split over the bound mesh axes."""
        try:
            from jax.experimental.shard_map import shard_map
        except ImportError:  # pragma: no cover - jax >= 0.6 spelling
            from jax import shard_map
        spec = P(self.binding)
        return shard_map(fn, mesh=self.mesh, in_specs=spec, out_specs=spec,
                         check_rep=False)

    def gather_tails(self, t: jax.Array) -> jax.Array:
        """All-gather per-chunk tail summaries across shards: local
        ``(chunks/size, ...)`` -> global ``(chunks, ...)``.  The one
        collective the bucketed scans pay — O(S) elements, not O(n)."""
        return jax.lax.all_gather(t, self.axes, axis=0, tiled=True)

    def local_chunks(self, x: jax.Array, n_local: int) -> jax.Array:
        """Slice a combined ``(chunks, ...)`` array down to this shard's
        ``n_local`` chunks (the inverse of :meth:`gather_tails`)."""
        idx = 0
        for a in self.axes:
            idx = idx * self.mesh.shape[a] + jax.lax.axis_index(a)
        return jax.lax.dynamic_slice_in_dim(x, idx * n_local, n_local, 0)


@contextlib.contextmanager
def flow_mesh(n_devices: Optional[int] = None, axis: str = "data",
              rules: Optional[Dict[str, Axis]] = None):
    """Bind an N-device mesh with the Peregrine placement rules in one shot.

    Builds a 1-D mesh of ``n_devices`` (default: every visible device) on
    logical axis ``axis``, sets it ambient, and binds
    ``{"flow_shards": axis, "tenants": axis}`` (override with ``rules``) —
    the two rules the bucketed FC engine and the multi-tenant engine place
    themselves by.  The forced-host-device harness
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``; DESIGN.md §12)
    plus this context manager is the whole multi-device story on CPU CI;
    on a real accelerator mesh the same call binds physical devices.
    """
    n = jax.device_count() if n_devices is None else int(n_devices)
    mesh = jax.make_mesh((n,), (axis,))
    with contextlib.ExitStack() as es:
        es.enter_context(set_mesh(mesh))
        es.enter_context(use_rules(
            {"flow_shards": axis, "tenants": axis} if rules is None
            else rules))
        yield mesh


def named_shardings(mesh, tree):
    """PartitionSpec/None leaves -> ``NamedSharding`` on ``mesh``.

    Older jax's ``jax.jit`` rejects bare PartitionSpecs in
    ``in_shardings``/``out_shardings``; newer jax resolves them against the
    ambient mesh.  Converting explicitly works on both.  ``None`` leaves
    (and ``None`` tree prefixes) keep their "unspecified — let the compiler
    propagate" meaning and pass through untouched.
    """
    from jax.sharding import NamedSharding

    def conv(x):
        return NamedSharding(mesh, x) if isinstance(x, P) else x

    return jax.tree_util.tree_map(
        conv, tree, is_leaf=lambda x: x is None or isinstance(x, P))


class AxisRules:
    def __init__(self, rules: Dict[str, Axis]):
        self.rules = dict(rules)

    def spec(self, names: Sequence[Optional[str]]) -> P:
        return P(*[self.rules.get(n) if n else None for n in names])


class _State(threading.local):
    def __init__(self):
        self.rules: Optional[AxisRules] = None


_STATE = _State()


@contextlib.contextmanager
def use_rules(rules: Optional[Dict[str, Axis]]):
    prev = _STATE.rules
    _STATE.rules = AxisRules(rules) if rules is not None else None
    try:
        yield _STATE.rules
    finally:
        _STATE.rules = prev


def current_rules() -> Optional[AxisRules]:
    return _STATE.rules


def logical_spec(names: Sequence[Optional[str]]) -> P:
    r = _STATE.rules
    if r is None:
        return P(*[None] * len(names))
    return r.spec(names)


def lshard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Constrain ``x`` to the sharding implied by logical axis ``names``.

    No-op when no rules are bound (single-device paths).
    """
    r = _STATE.rules
    if r is None:
        return x
    assert x.ndim == len(names), (x.shape, names)
    return jax.lax.with_sharding_constraint(x, r.spec(names))
