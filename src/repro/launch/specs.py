"""Per-cell step functions + ShapeDtypeStruct inputs for the dry-run.

``input_specs(arch, shape)`` returns abstract stand-ins (weak-type-correct,
shardable, zero allocation) for every input of the lowered step:
  train_*    -> (train_state, {tokens|embeds, labels})     for train_step
  prefill_*  -> (params, batch)                            for prefill_step
  decode_*   -> (params, tokens(B,1), cache)               for serve_step

Per-arch training posture (applied automatically, recorded in EXPERIMENTS):
  >100B params : bf16 params, adafactor (factored 2nd moment), remat=full,
                 FSDP param sharding over the DP axes, ZeRO-1
  10–100B      : bf16 params, adamw fp32 moments (ZeRO-1 + FSDP), remat=dots
  <10B         : fp32 params, adamw, remat=none, plain DP+TP
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, TrainConfig
from repro.models import build_model
from repro.models import transformer as tf
from repro.training.train_step import init_train_state, make_train_step

LONG_CONTEXT_WINDOW = 4096   # sliding window for zamba2 shared attn @ 500k


def arch_for_cell(arch_name: str, shape: ShapeConfig) -> ArchConfig:
    cfg = get_arch(arch_name)
    if shape.name == "long_500k" and cfg.family == "hybrid":
        cfg = dataclasses.replace(cfg, window=LONG_CONTEXT_WINDOW)
    return cfg


def train_config_for(cfg: ArchConfig) -> TrainConfig:
    from repro.distributed import flags as _flags
    n = cfg.param_count()
    override = _flags.remat_override()
    if override is not None:
        tc = _base_tc(n)
        return dataclasses.replace(tc, remat=override)
    return _base_tc(n)


def _base_tc(n: float) -> TrainConfig:
    if n > 100e9:
        return TrainConfig(param_dtype="bfloat16", optimizer="adafactor",
                           remat="full", zero1=True)
    if n > 10e9:
        return TrainConfig(param_dtype="bfloat16", optimizer="adamw",
                           opt_state_dtype="float32", remat="full", zero1=True)
    return TrainConfig(param_dtype="float32", optimizer="adamw", remat="dots")


def use_fsdp(cfg: ArchConfig) -> bool:
    return cfg.param_count() > 10e9


def abstract_state(cfg: ArchConfig, tc: TrainConfig):
    model = build_model(cfg)
    return jax.eval_shape(
        lambda k: init_train_state(model, tc, k), jax.random.PRNGKey(0))


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    model = build_model(cfg)
    return jax.eval_shape(lambda k: model.init_params(k, dtype=dtype),
                          jax.random.PRNGKey(0))


def abstract_cache(cfg: ArchConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: tf.init_cache(cfg, batch, max_seq, dtype))


def batch_struct(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    out = {"labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.embed_inputs:
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:
        out["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_in), jnp.bfloat16)
    return out


def input_specs(arch_name: str, shape_name: str, cfg: ArchConfig = None):
    """(step_fn, abstract_inputs tuple, cfg, tc) for one dry-run cell.

    ``cfg`` overrides the registry config (used for the truncated-depth
    unrolled lowerings that feed the roofline cost extrapolation)."""
    shape = SHAPES[shape_name]
    if cfg is None:
        cfg = arch_for_cell(arch_name, shape)
    model = build_model(cfg)
    tc = train_config_for(arch_for_cell(arch_name, shape))

    if shape.kind == "train":
        state = abstract_state(cfg, tc)
        batch = batch_struct(cfg, shape)
        step = make_train_step(model, tc)
        return step, (state, batch), cfg, tc

    if shape.kind == "prefill":
        params = abstract_params(cfg)
        batch = batch_struct(cfg, shape)

        def prefill_step(params, batch):
            logits, aux, cache = tf.forward(params, cfg, batch,
                                            build_cache=not cfg.is_encoder,
                                            max_seq=shape.seq_len)
            return logits[:, -1:], cache

        return prefill_step, (params, batch), cfg, tc

    # decode
    params = abstract_params(cfg)
    cache = abstract_cache(cfg, shape.global_batch, shape.seq_len)
    # position the cache at seq_len-1 (full context) — pos is a traced input
    batch = batch_struct(cfg, shape)

    def serve_step(params, tokens, cache):
        return tf.decode_step(params, cfg, tokens, cache)

    return serve_step, (params, batch["tokens"], cache), cfg, tc
