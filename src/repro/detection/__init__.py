from repro.detection.kitnet import KitNet, train_kitnet, score_kitnet  # noqa: F401
from repro.detection.metrics import auc, f1_at_fpr  # noqa: F401
from repro.detection.runner import run_peregrine, run_kitsune_baseline  # noqa: F401
