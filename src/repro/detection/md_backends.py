"""MD (scoring) backend registry — the detection-side twin of the FC registry.

Peregrine's division of labour (Fig. 3) makes feature computation swappable
behind ``repro.core.backends.compute_features``; this module does the same
for the *MD stage* (§3.4 KitNET): the service never cares how the ensemble
reconstruction RMSEs were produced.

    scores = score_records(net, feats, backend="pallas")

Backends (all emit identical per-record anomaly scores, ≤1e-5 apart):

  * ``einsum`` — the batched-einsum path (detection/kitnet.py): every
    ensemble AE runs inside ONE padded einsum, whole scoring path under a
    single ``jax.jit``.  The default, and the training-time reference.
  * ``pallas`` — the fused ensemble kernel (kernels/kitnet_ae.py):
    gather + normalise on the host graph, then one ``pallas_call`` grid of
    (AE, batch-tile) steps — two MXU matmuls + sigmoids + masked RMSE per
    step, the reconstruction never materialised in HBM.  Runs in interpret
    mode on CPU; ``REPRO_PALLAS_COMPILE=1`` compiles it on TPU (read per
    call, ``interpret=`` wins — same plumbing as the FC kernels).

Each registered backend supplies the *ensemble* stage
``fn(params, idx, mask, xn) -> (B, k) RMSE`` plus a full scoring function;
``ensemble_rmse_records`` exposes the former so ``train_kitnet`` can run its
training-set RMSE pass (output-AE normalisation + training data) through the
same backend it will score with.  Design rationale: DESIGN.md §3.

``register_md_backend`` is the extension point (e.g. a quantised or
distilled scorer).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class _MDBackend(NamedTuple):
    score: Callable      # fn(net, X (B,F) jnp) -> (B,) scores
    ensemble: Callable   # fn(params, idx, mask, xn (B,F)) -> (B,k) RMSE
    options: frozenset   # kwarg names the backend accepts


_REGISTRY: Dict[str, _MDBackend] = {}

# legacy / convenience spellings
_ALIASES = {"batched": "einsum", "kernel": "pallas", "fused": "pallas"}


def register_md_backend(name: str, *, score: Callable, ensemble: Callable,
                        options: Tuple[str, ...] = ()):
    """Register an MD backend: a full scoring fn + its ensemble stage.

    ``options`` names the keyword options the backend accepts; anything
    else passed via ``md_kw``/``**kw`` raises instead of being silently
    swallowed (a misspelled tuning flag must not measure the default).
    """
    _REGISTRY[name] = _MDBackend(score=score, ensemble=ensemble,
                                 options=frozenset(options))


def validate_md_options(backend: str, kw: Dict) -> str:
    """Resolve ``backend`` and reject options it does not accept."""
    name = resolve_md_backend(backend)
    unknown = set(kw) - _REGISTRY[name].options
    if unknown:
        raise TypeError(
            f"MD backend {name!r} got unexpected options {sorted(unknown)}; "
            f"accepted: {sorted(_REGISTRY[name].options)}")
    return name


def available_md_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_md_backend(name: str) -> str:
    """Canonical MD backend name (alias-aware); raises on unknown names."""
    name = _ALIASES.get(name, name)
    if name not in _REGISTRY:
        raise ValueError(f"unknown MD backend {name!r}; "
                         f"available: {available_md_backends()}")
    return name


def default_md_backend() -> str:
    return "einsum"


# ---------------------------------------------------------------------------
# einsum — the batched reference path (one jit over the whole score)
# ---------------------------------------------------------------------------
def _score_einsum(net, X, **_kw):
    from repro.detection.kitnet import _score
    return _score(net.params, net.idx, net.mask, net.norm_min, net.norm_max,
                  net.out_min, net.out_max, X)


def _ensemble_einsum(params, idx, mask, xn, **_kw):
    from repro.detection.kitnet import ensemble_rmse
    return ensemble_rmse(params, idx, mask, xn)


# ---------------------------------------------------------------------------
# pallas — fused ensemble kernel (kernels/kitnet_ae.kitnet_ensemble)
# ---------------------------------------------------------------------------
def _ensemble_pallas(params, idx, mask, xn, *, bb: int = 128, interpret=None,
                     **_kw):
    from repro.kernels import ops
    sub = xn[:, idx]                                   # (B, k, m) gather
    return ops.kitnet_ensemble(sub, params["W1"], params["b1"],
                               params["W2"], params["b2"], mask,
                               bb=bb, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bb", "interpret"))
def _score_pallas_jit(params, idx, mask, lo, hi, r_lo, r_hi, X, *,
                      bb: int, interpret: bool):
    from repro.detection.kitnet import _normalize, output_rmse
    from repro.kernels.kitnet_ae import kitnet_ensemble
    xn = _normalize(X, lo, hi)
    sub = xn[:, idx]                                   # (B, k, m) gather
    r = kitnet_ensemble(sub, params["W1"], params["b1"],
                        params["W2"], params["b2"], mask,
                        bb=bb, interpret=interpret)
    rn = _normalize(r, r_lo, r_hi)
    return output_rmse(params, rn)


def _score_pallas(net, X, *, bb: int = 128, interpret=None, **_kw):
    # one jit over the whole scoring path (like the einsum _score) —
    # interpret is resolved from the environment HERE, per call, so it can
    # be a static jit arg without freezing REPRO_PALLAS_COMPILE at import
    from repro.kernels.ops import interpret_default
    interpret = interpret_default() if interpret is None else interpret
    return _score_pallas_jit(net.params, net.idx, net.mask, net.norm_min,
                             net.norm_max, net.out_min, net.out_max, X,
                             bb=bb, interpret=interpret)


register_md_backend("einsum", score=_score_einsum, ensemble=_ensemble_einsum)
register_md_backend("pallas", score=_score_pallas, ensemble=_ensemble_pallas,
                    options=("bb", "interpret"))


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------
def score_records(net, feats: np.ndarray, backend: str = "einsum",
                  **kw) -> np.ndarray:
    """Anomaly RMSE per feature record through the selected MD backend.

    ``net`` is a fitted :class:`~repro.detection.kitnet.KitNet`; ``feats``
    is the (B, F) record matrix.  Extra kwargs go to the backend (e.g.
    ``bb=``/``interpret=`` for pallas).  Per-record scores are independent
    of the batch they arrive in, so chunked streaming scoring is
    bit-identical to one-batch scoring for every backend.
    """
    name = validate_md_options(backend, kw)
    X = jnp.asarray(feats, jnp.float32)
    return np.asarray(_REGISTRY[name].score(net, X, **kw))


def md_score_fn(backend: str = "einsum", **kw) -> Callable:
    """The selected backend's *traceable* scoring callable ``fn(net, X)``.

    ``score_records`` wraps the result in host arrays; this accessor hands
    out the raw jax-level function instead so a caller can inline the MD
    stage into a larger jit (the fused serving step) — ``net`` is a
    :class:`~repro.detection.kitnet.KitNet` pytree, ``X`` a (B, F) jnp
    array, and the return value stays on device.
    """
    name = validate_md_options(backend, kw)
    score = _REGISTRY[name].score
    return lambda net, X: score(net, X, **kw)


def ensemble_rmse_records(params, idx, mask, xn, backend: str = "einsum",
                          **kw) -> jnp.ndarray:
    """The ensemble stage alone: normalised records (B, F) -> (B, k) RMSE.

    Used by ``train_kitnet`` so its training-set RMSE pass (which fixes the
    output AE's normalisation and training inputs) runs through the same
    backend later used for scoring.
    """
    name = validate_md_options(backend, kw)
    return _REGISTRY[name].ensemble(params, idx, mask, xn, **kw)
