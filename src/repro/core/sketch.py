"""Count-Min sketch flow state — bounded memory under unbounded cardinality.

The dense backend direct-indexes ``hash(key) % n_slots``: past the slot
budget, flows silently merge.  This backend stores every decay atom in R
independently-hashed rows of width W (Count-Min), reads the per-atom
minimum across rows, and writes with **conservative update** (only raise a
cell to the new estimate, never beyond — Estan & Varghese), so the
estimate stays a one-sided overestimate of the true decayed statistic and
collisions perturb a flow only while *all R* of its rows are contended.
This is the switch-register compromise the 100G software detectors make
(Whisper/OctoSketch lineage, PAPERS.md) translated to our decay atoms.

Layout (`init_sketch_state`): the dense tables with the slot axis replaced
by (rows, width) — uni atoms ``(N_UNI, R, W, N_DECAY)``, bi atoms
``(N_BI, R, W, 2, N_DECAY)``, channel SR state ``(N_BI, R, W, N_DECAY)``
plus a ``sw`` per-row channel packet count used to pick the least-collided
row for the *signed* SR statistic (min is a biased estimator for signed
values, so SR reads the row with the smallest conservative packet count —
at R=1 that is the only row and the choice is vacuous).  ``evict_age`` is
a traced f32 scalar leaf: cells idle longer than this many seconds are
treated as empty on access (aging/eviction — long-running streams stop
aliasing dead flows); 0 disables aging.

Row r of key type k hashes with salt ``KEY_SALTS[k] ^ (r * 0x85EBCA6B)``:
row 0 uses the dense salt, so a sketch with ``rows=1, n_slots=W`` maps
flows to exactly the dense slots and the STATE UPDATE degenerates to the
dense serial oracle bit-for-bit (the candidate formulation in
``_cu_update`` exists to preserve XLA's fma contraction of the oracle's
``v·δ + inc``).  The emitted sigma/mag/rad statistics — pure outputs
that never feed back into state — agree to float rounding only: XLA
contracts the variance expression differently in the two scan bodies,
and that choice is not controllable from the source.  Both halves are
pinned in tests/test_state_backends.py — the collision-free sizing of
the acceptance criteria.

Two implementations of the same update:

  * :func:`process_sketch` — pure-JAX reference, a per-packet ``lax.scan``
    mirroring ``core/pipeline._packet_step`` with R-row gathers/scatters.
    Conservative update is order-dependent THROUGH the cross-row min, so
    the sketch cannot ride the segmented-scan machinery (the associative
    reformulation dense ``scan``/``bucketed`` use does not exist here);
    like the serial oracle it is packet-serial.
  * ``kernels/sketch_update.sketch_update_full`` — the Pallas row-update
    kernel (hash rows precomputed host-side → in-kernel row gather →
    min/conservative-add combine), selected via ``fc_backend="pallas"``.

Dispatch: ``compute_features(state, pkts, backend=...)`` identifies a
sketch state structurally (``state_backend_of``) and routes here; the
``backend=`` name then only picks the implementation (``pallas`` → the
kernel, anything else → the reference scan).  Exact arithmetic only: the
switch round-robin mode is tied to the dense rr counters.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import arith
from repro.core.state import (
    KEY_SALTS, LAMBDAS, N_BI, N_DECAY, N_UNI, StateBackend, hash_fields,
    key_fields, register_state_backend,
)

_LAM = jnp.asarray(LAMBDAS, jnp.float32)

# row-salt derivation constant (murmur3 fmix): row 0 keeps the dense salt
_ROW_SALT_MIX = 0x85EBCA6B


def row_salt(base: int, r: int) -> int:
    """Salt of sketch row ``r`` for a key type with dense salt ``base``."""
    return (base ^ ((r * _ROW_SALT_MIX) & 0xFFFFFFFF)) & 0xFFFFFFFF


def init_sketch_state(n_slots: int, rows: int = 4,
                      evict_age: float = 0.0) -> Dict:
    """Fresh Count-Min flow tables: ``rows`` hashed rows of width
    ``n_slots`` per key type; ``evict_age`` seconds of idleness after
    which a cell reads as empty (0 = no aging)."""
    if rows < 1:
        raise ValueError(f"sketch needs at least one row, got {rows}")
    R, W = int(rows), int(n_slots)
    z = jnp.zeros
    return {
        "uni": {
            "last_t": z((N_UNI, R, W, N_DECAY)) - 1.0,
            "w": z((N_UNI, R, W, N_DECAY)),
            "ls": z((N_UNI, R, W, N_DECAY)),
            "ss": z((N_UNI, R, W, N_DECAY)),
        },
        "bi": {
            "last_t": z((N_BI, R, W, 2, N_DECAY)) - 1.0,
            "w": z((N_BI, R, W, 2, N_DECAY)),
            "ls": z((N_BI, R, W, 2, N_DECAY)),
            "ss": z((N_BI, R, W, 2, N_DECAY)),
            "res_last": z((N_BI, R, W, 2, N_DECAY)),
            "sr": z((N_BI, R, W, N_DECAY)),
            "sr_last_t": z((N_BI, R, W, N_DECAY)) - 1.0,
            "sw": z((N_BI, R, W, N_DECAY)),
        },
        "evict_age": jnp.float32(evict_age),
    }


def sketch_rows(state: Dict) -> int:
    return state["uni"]["w"].shape[1]


def sketch_width(state: Dict) -> int:
    return state["uni"]["w"].shape[2]


def sketch_packet_rows(pkts: Dict[str, jax.Array], rows: int,
                       width: int) -> Dict[str, jax.Array]:
    """Per-packet sketch column indices, (n, rows) per key type, plus the
    channel ``dir`` bit — the multi-row analogue of ``packet_slots``
    (identical canonicalisation via ``key_fields``; row 0 == the dense
    slot mapping of a width-``width`` dense table)."""
    fields, dirb = key_fields(pkts)
    w = jnp.uint32(width)
    out = {"dir": dirb}
    for k, f in fields.items():
        cols = [(hash_fields(f, row_salt(KEY_SALTS[k], r)) % w)
                .astype(jnp.int32) for r in range(rows)]
        out[k] = jnp.stack(cols, axis=-1)
    return out


# ---------------------------------------------------------------------------
# Pure-JAX reference update (per-packet lax.scan)
# ---------------------------------------------------------------------------
def _cu_update(lt, w, ls, ss, t, x, age):
    """Conservative-update decay + atom update across rows.

    lt/w/ls/ss: (K, R, N_DECAY) gathered cells; t/x scalars; age the
    eviction threshold (0 disables).  Returns the updated cells plus the
    per-atom Count-Min estimates (K, N_DECAY) — the post-update min across
    rows.  At R=1 the min is over one row, every max resolves to the
    candidate ``v·δ + inc``, and the stored state is bit-for-bit the
    oracle's ``_stream_update`` exact path.
    """
    dt = jnp.maximum(t - lt, 0.0)
    dead = (lt < 0.0) | ((age > 0.0) & (dt > age))
    delta = jnp.where(dead, 0.0, jnp.exp2(-_LAM * dt))
    # Per-row candidates v·δ + inc keep the oracle's mul+add expression
    # shape: XLA contracts it to an fma inside the scan, and a second use
    # of the raw product would block that contraction (verified on CPU),
    # so the conservative-update max compares ``cand - inc`` instead —
    # bitwise ``est`` whenever the estimate wins (always at R=1, where
    # min-of-candidates is the candidate and the whole update is
    # bit-for-bit the dense serial oracle), and within ~2 ulp of the
    # decayed value on collided rows where the row's own value wins.
    # the unit increment rides through an optimization barrier: as a
    # literal, XLA folds ``(w·δ + 1) - 1`` back to the raw product, whose
    # second use then blocks the fma (the traced x/x² increments of
    # ls/ss don't need the shield)
    one = jax.lax.optimization_barrier(jnp.float32(1.0))
    cw = w * delta + one
    cls = ls * delta + x
    css = ss * delta + x ** 2
    ew = jnp.min(cw, axis=1, keepdims=True)
    els = jnp.min(cls, axis=1, keepdims=True)
    ess = jnp.min(css, axis=1, keepdims=True)
    w2 = jnp.maximum(cw - one, ew)
    ls2 = jnp.maximum(cls - x, els)
    ss2 = jnp.maximum(css - x ** 2, ess)
    lt2 = jnp.broadcast_to(t, lt.shape)
    est = (ew[:, 0], els[:, 0], ess[:, 0])
    return lt2, w2, ls2, ss2, est


def _stats(w, ls, ss):
    mu = arith.div(ls, w, "exact")
    var = jnp.abs(arith.div(ss, w, "exact") - arith.square(mu, "exact"))
    return mu, var, arith.sqrt(var, "exact")


def _sketch_packet_step(tables: Dict, pkt: Dict, age) -> Tuple[Dict, jax.Array]:
    """One packet through the sketch — mirrors ``pipeline._packet_step``
    (exact mode) with R-row conservative-update access."""
    t, x = pkt["ts"], pkt["length"]
    R = tables["uni"]["w"].shape[1]
    ri = jnp.arange(R)[None, :]
    feats = []

    # ---- unidirectional key types ----
    uni = tables["uni"]
    ki = jnp.arange(N_UNI)[:, None]
    cols = jnp.stack([pkt["src_mac_ip"], pkt["src_ip"]])       # (2, R)
    g = lambda a: a[ki, ri, cols]                              # (2, R, ND)
    lt2, w2, ls2, ss2, (ew, els, ess) = _cu_update(
        g(uni["last_t"]), g(uni["w"]), g(uni["ls"]), g(uni["ss"]), t, x, age)
    mu, var, sigma = _stats(ew, els, ess)
    feats.append(jnp.stack([ew, mu, sigma], axis=-1).reshape(-1))
    s = lambda name, v: uni[name].at[ki, ri, cols].set(v)
    tables = {**tables, "uni": {"last_t": s("last_t", lt2), "w": s("w", w2),
                                "ls": s("ls", ls2), "ss": s("ss", ss2)}}

    # ---- bidirectional key types ----
    bi = tables["bi"]
    kb = jnp.arange(N_BI)[:, None]
    bcols = jnp.stack([pkt["channel"], pkt["socket"]])         # (2, R)
    d = pkt["dir"]
    o = 1 - d
    own = lambda a: a[kb, ri, bcols, d]                        # (2, R, ND)
    lt_o, w_o, ls_o, ss_o, (ew_o, els_o, ess_o) = _cu_update(
        own(bi["last_t"]), own(bi["w"]), own(bi["ls"]), own(bi["ss"]),
        t, x, age)
    mu_o, var_o, sig_o = _stats(ew_o, els_o, ess_o)

    # opposite-direction stats: stored values (stale, as on the switch),
    # aged-out cells read as empty, then the Count-Min min across rows
    opp = lambda a: a[kb, ri, bcols, o]
    lt_p = opp(bi["last_t"])
    zap = (age > 0.0) & ((t - lt_p) > age)
    rd = lambda a: jnp.min(jnp.where(zap, 0.0, opp(a)), axis=1)  # (2, ND)
    w_p, ls_p, ss_p = rd(bi["w"]), rd(bi["ls"]), rd(bi["ss"])
    mu_p, var_p, sig_p = _stats(w_p, ls_p, ss_p)

    # SR (decayed sum of cross-direction residual products): every row
    # keeps its own sr/res_last stream; the emitted value comes from the
    # row with the smallest conservative channel count sw (least collided)
    ch = lambda name: bi[name][kb, ri, bcols]                  # (2, R, ND)
    sr, sr_lt, sw = ch("sr"), ch("sr_last_t"), ch("sw")
    res_last_o = opp(bi["res_last"])                           # (2, R, ND)
    r_feat = x - mu_o                                          # (2, ND)
    dt_sr = jnp.maximum(t - sr_lt, 0.0)
    evict_sr = (age > 0.0) & (dt_sr > age)
    dsr = jnp.where((sr_lt < 0.0) | evict_sr, 0.0, jnp.exp2(-_LAM * dt_sr))
    r_opp = jnp.where(evict_sr, 0.0, res_last_o)
    sr2 = sr * dsr + r_feat[:, None, :] * r_opp                # (2, R, ND)
    sw_now = sw * dsr
    m_sw = jnp.min(sw_now, axis=1, keepdims=True)
    sw2 = jnp.maximum(sw_now, m_sw + 1.0)
    best = jnp.argmin(sw2, axis=1)                             # (2, ND)
    sr_est = jnp.take_along_axis(sr2, best[:, None, :], axis=1)[:, 0]

    mag = arith.sqrt(arith.square(mu_o, "exact")
                     + arith.square(mu_p, "exact"), "exact")
    rad = arith.sqrt(arith.square(var_o, "exact")
                     + arith.square(var_p, "exact"), "exact")
    cov = arith.div(sr_est, ew_o + w_p, "exact")
    pcc = arith.div(cov, sig_o * sig_p, "exact")
    feats.append(jnp.stack([ew_o, mu_o, sig_o, mag, rad, cov, pcc],
                           axis=-1).reshape(-1))

    sb = lambda name, v: bi[name].at[kb, ri, bcols, d].set(v)
    tables = {**tables, "bi": {
        "last_t": sb("last_t", lt_o), "w": sb("w", w_o),
        "ls": sb("ls", ls_o), "ss": sb("ss", ss_o),
        "res_last": sb("res_last",
                       jnp.broadcast_to(r_feat[:, None, :], sr2.shape)),
        "sr": bi["sr"].at[kb, ri, bcols].set(sr2),
        "sr_last_t": bi["sr_last_t"].at[kb, ri, bcols].set(
            jnp.broadcast_to(t, sr2.shape)),
        "sw": bi["sw"].at[kb, ri, bcols].set(sw2),
    }}
    return tables, jnp.concatenate(feats)


@jax.jit
def process_sketch(state: Dict, pkts: Dict[str, jax.Array]
                   ) -> Tuple[Dict, jax.Array]:
    """Pure-JAX reference sketch update: per-packet ``lax.scan`` (the
    conservative update's cross-row min breaks the associativity the
    segmented-scan backends exploit, so packet-serial is inherent).
    Returns ``(new_state, feats (n, N_FEATURES))``.
    """
    rows = sketch_packet_rows(pkts, sketch_rows(state), sketch_width(state))
    xs = {"ts": pkts["ts"].astype(jnp.float32),
          "length": pkts["length"].astype(jnp.float32), **rows}
    age = state["evict_age"]
    tables = {k: state[k] for k in ("uni", "bi")}

    def step(tb, x):
        return _sketch_packet_step(tb, x, age)

    tables, feats = jax.lax.scan(step, tables, xs)
    return {**tables, "evict_age": age}, feats


# ---------------------------------------------------------------------------
# compute dispatch + backend registration
# ---------------------------------------------------------------------------
def compute_features_sketch(state: Dict, pkts: Dict[str, jax.Array],
                            mode: str = "exact", fc_backend: str = "scan",
                            chunk: int = 256, interpret=None,
                            **_kw) -> Tuple[Dict, jax.Array]:
    """Route a sketch-state batch to an implementation: ``pallas`` → the
    row-update kernel, anything else → the pure-JAX reference.  Partition
    kwargs of the dense backends (``buckets``/``shards``) are accepted and
    ignored — partitioning belongs to the dense slot layout."""
    if mode != "exact":
        raise ValueError("the sketch state backend supports exact "
                         f"arithmetic only, got mode={mode!r} (switch-mode "
                         "round-robin decay is tied to the dense rr "
                         "counters)")
    if fc_backend == "pallas":
        from repro.kernels.ops import sketch_update_full
        return sketch_update_full(state, pkts, chunk=chunk,
                                  interpret=interpret)
    return process_sketch(state, pkts)


register_state_backend(StateBackend(
    name="sketch",
    init=init_sketch_state,
    slots=sketch_width,
    matches=lambda s: isinstance(s, dict) and "evict_age" in s,
    config=lambda s: {"rows": sketch_rows(s),
                      "evict_age": float(jax.device_get(s["evict_age"]))},
    compute=compute_features_sketch,
))
