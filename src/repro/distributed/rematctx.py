"""Remat (activation-checkpoint) policy context.

Model stacks consult ``current_remat()`` when building their layer scans so
TrainConfig.remat reaches the layer body without threading a kwarg through
every family's forward signature.
"""
from __future__ import annotations

import contextlib
import threading

import jax


class _State(threading.local):
    def __init__(self):
        self.policy = "none"


_STATE = _State()


@contextlib.contextmanager
def use_remat(policy: str):
    prev = _STATE.policy
    _STATE.policy = policy
    try:
        yield
    finally:
        _STATE.policy = prev


def current_remat() -> str:
    return _STATE.policy


def maybe_remat(fn):
    """Wrap a scan body according to the active policy."""
    policy = _STATE.policy
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    if policy == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    raise ValueError(policy)
