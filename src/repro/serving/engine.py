"""Multi-tenant async detection engine: N tenant streams, one device.

``DetectionService`` is one synchronous loop over one stream; deployment
(ROADMAP "millions of users") is a switch feeding MANY concurrent tenant
streams into one control-plane detector.  ``DetectionEngine`` multiplexes
them (DESIGN.md §10):

* **Bounded state pool.**  Per-tenant flow-table state lives in a
  ``core.state.StatePool`` — one STACKED pytree with a leading tenant
  axis, so N tenants cost one device allocation per table; tenant slots
  are alloc'd/freed/reset as streams attach and detach.
* **Cross-tenant fused batching.**  Ready tenants' chunks are packed into
  ONE donated jit call (``serving/fused.make_tenant_step``): the service's
  per-chunk core — FC → on-device epoch gather → KitNET → threshold —
  vmapped over the tenant axis, tenant ids carried with every lane so
  states and per-tenant epoch counters never mix.  Per-lane results are
  bitwise the single-tenant step's (tests/test_engine.py), so one tenant
  through the engine reproduces ``DetectionService.process_stream``
  bit for bit.
* **Backpressure.**  Each tenant has a bounded ingress buffer
  (``queue_depth`` chunks); ``submit`` sheds overflow (drop-tail), never
  blocks, and the shed count is reported per tenant — the engine cannot
  deadlock on a slow device.
* **Async dispatch-before-drain.**  As in ``process_stream``, batch k+1
  is dispatched to the device before batch k's O(records) results are
  drained, so steady-state throughput is bounded by the fused step.
* **Operational surface.**  Per-tenant p50/p99 chunk latency, aggregate
  pps, per-tenant drop/record/alarm counters (``stats()``), and
  daemon-style structured alarm delivery: a per-tenant CSV or JSONL alarm
  log (``alarm_dir=``) — the DPDK detector's ``run_background.sh`` +
  alarm-CSV operational shape.

One fitted detector (net + threshold) serves every tenant; isolation is
state isolation, not model isolation.  Donation contract (DESIGN.md §8)
applies to the pool exactly as to the single-stream state.
"""
from __future__ import annotations

import collections
import json
import os
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import resolve_backend
from repro.core.state import (StatePool, slot_collisions, state_backend_of,
                              state_config, state_slots)
from repro.detection.md_backends import (default_md_backend,
                                         validate_md_options)


class DetectionEngine:
    """Continuous-batching detection engine over a bounded tenant pool.

    Parameters
    ----------
    net, threshold:
        The fitted KitNET and alarm threshold shared by every tenant
        (train once via ``DetectionService``, then ``from_service``).
    epoch, n_slots, backend/backend_kw, md_backend/md_kw, mode:
        The per-chunk pipeline configuration, identical in meaning to
        ``DetectionService``; only exact mode is supported (the engine
        rides the fused device-resident path).
    n_tenants:
        State-pool capacity — the hard bound on concurrently attached
        tenant streams.
    chunk:
        Packets per fused-step lane.  Full chunks are batched across
        tenants; partial tails are flushed at ``flush()``.
    queue_depth:
        Ingress bound per tenant, in chunks: at most ``queue_depth *
        chunk`` packets may sit buffered; ``submit`` sheds the excess.
    max_batch:
        Most tenant lanes per fused call (default: ``n_tenants``).
    alarm_dir / alarm_format:
        When set, every drained alarm is appended to a per-tenant
        structured log ``<alarm_dir>/tenant<id>.{csv|jsonl}``.
    state_backend / state_kw:
        Flow-table layout of the tenant pool: ``"dense"`` (default) or
        ``"sketch"`` (``state_kw={"rows": R, "evict_age": ...}``);
        ``from_service`` inherits both from the service's state.  Dense
        pools additionally report per-tenant ``slot_collisions`` — the
        distinct flow keys that aliased an occupied slot per chunk.
    """

    def __init__(self, net, threshold: float, *, epoch: int = 1024,
                 n_slots: int = 8192, n_tenants: int = 4, chunk: int = 2048,
                 queue_depth: int = 8, max_batch: Optional[int] = None,
                 backend: Optional[str] = None, backend_kw: Optional[Dict] = None,
                 md_backend: Optional[str] = None, md_kw: Optional[Dict] = None,
                 mode: str = "exact", alarm_dir: Optional[str] = None,
                 alarm_format: str = "csv",
                 state_backend: str = "dense",
                 state_kw: Optional[Dict] = None):
        if mode != "exact":
            raise ValueError("DetectionEngine rides the fused exact-mode "
                             f"path; mode {mode!r} is not supported")
        if chunk < 1 or queue_depth < 1:
            raise ValueError("chunk and queue_depth must be positive")
        if alarm_format not in ("csv", "jsonl"):
            raise ValueError(f"alarm_format must be csv|jsonl, "
                             f"got {alarm_format!r}")
        self.net = net
        self.threshold = float(np.float32(threshold))
        self.epoch = int(epoch)
        self.mode = mode
        self.backend = resolve_backend(backend if backend is not None
                                       else "scan")
        self.backend_kw = dict(backend_kw or {})
        self.md_kw = dict(md_kw or {})
        self.md_backend = validate_md_options(
            md_backend if md_backend is not None else default_md_backend(),
            self.md_kw)
        self.chunk = int(chunk)
        self.queue_depth = int(queue_depth)
        self.max_batch = int(max_batch if max_batch is not None else n_tenants)
        self.state_backend = state_backend
        self.state_kw = dict(state_kw or {})
        self.n_slots = int(n_slots)
        self.pool = StatePool(n_tenants, n_slots, state_backend=state_backend,
                              **self.state_kw)
        self.alarm_dir = alarm_dir
        self.alarm_format = alarm_format
        # per-tenant host-side stream state (created by add_tenant)
        self._buf: Dict[int, collections.deque] = {}
        self._buffered: Dict[int, int] = {}
        self._pkt_count: Dict[int, int] = {}
        self._results: Dict[int, List] = {}
        self._lat: Dict[int, List[float]] = {}
        self._counters: Dict[int, Dict[str, int]] = {}
        self._alarm_files: Dict[int, object] = {}
        # in-flight fused batches, oldest first (dispatch-before-drain)
        self._inflight: collections.deque = collections.deque()
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        self._pkts_done = 0

    # ------------------------------------------------------------------
    # construction from a trained service
    # ------------------------------------------------------------------
    @classmethod
    def from_service(cls, svc, **kw) -> "DetectionEngine":
        """Build an engine that runs the SAME per-chunk pipeline as a
        fitted ``DetectionService`` (net, threshold, epoch, slot budget,
        FC/MD backend selection all inherited; override via ``kw``)."""
        assert svc.net is not None, "fit the service first"
        cfg = dict(epoch=svc.epoch, n_slots=state_slots(svc.state),
                   backend=svc.backend, backend_kw=svc.backend_kw,
                   md_backend=svc.md_backend, md_kw=svc.md_kw,
                   mode=svc.mode,
                   state_backend=state_backend_of(svc.state),
                   state_kw=state_config(svc.state))
        cfg.update(kw)
        return cls(svc.net, svc.threshold, **cfg)

    # ------------------------------------------------------------------
    # tenant lifecycle
    # ------------------------------------------------------------------
    def add_tenant(self) -> int:
        """Attach a new tenant stream: claims a pool slot (fresh flow
        tables, epoch counter at zero) and an empty ingress queue."""
        tid = self.pool.alloc()
        self._buf[tid] = collections.deque()
        self._buffered[tid] = 0
        self._pkt_count[tid] = 0
        self._results[tid] = [[], [], []]
        self._lat[tid] = []
        self._counters[tid] = {"pkts_in": 0, "pkts_dropped": 0,
                               "pkts_processed": 0, "records": 0, "alarms": 0,
                               "slot_collisions": 0}
        return tid

    def remove_tenant(self, tid: int) -> None:
        """Detach a tenant and free its pool slot.  Buffered packets are
        discarded; drain in-flight work first (``flush``) if the tenant's
        remaining results matter."""
        if self._inflight:
            self._drain_all()
        self.pool.free(tid)
        for d in (self._buf, self._buffered, self._pkt_count, self._results,
                  self._lat, self._counters):
            d.pop(tid, None)
        f = self._alarm_files.pop(tid, None)
        if f is not None:
            f.close()

    def seed_tenant(self, tid: int, state: Dict, pkt_count: int = 0) -> None:
        """Start tenant ``tid`` from an existing flow-table state (a COPY
        is installed) and stream position — e.g. hand a
        ``DetectionService``'s post-training tables over so the tenant
        stream continues exactly where the training capture stopped."""
        if self._inflight:
            self._drain_all()
        self.pool.write(tid, state)
        self._pkt_count[tid] = int(pkt_count)

    def reset_tenant(self, tid: int) -> None:
        """Fresh capture on an attached tenant: zero its flow tables and
        epoch counter, drop its buffered packets (results are kept)."""
        if self._inflight:
            self._drain_all()
        self.pool._check(tid)
        self.pool.reset(tid)
        self._buf[tid].clear()
        self._buffered[tid] = 0
        self._pkt_count[tid] = 0

    # ------------------------------------------------------------------
    # ingress with backpressure
    # ------------------------------------------------------------------
    def room(self, tid: int) -> int:
        """Packets tenant ``tid``'s bounded ingress buffer still accepts."""
        return self.queue_depth * self.chunk - self._buffered[tid]

    def submit(self, tid: int, pkts: Dict[str, np.ndarray]) -> int:
        """Offer a packet batch to tenant ``tid``'s ingress queue.

        Never blocks: accepts up to ``room(tid)`` packets (FIFO order
        preserved), SHEDS the rest (drop-tail), and returns the accepted
        count; ``stats()[tid]["pkts_dropped"]`` accumulates the shed
        packets.  This is the backpressure contract — a slow device can
        cost coverage, never liveness."""
        n = len(pkts["ts"])
        self._counters[tid]["pkts_in"] += n
        take = max(0, min(n, self.room(tid)))
        if take:
            piece = {k: np.asarray(v[:take]) for k, v in pkts.items()
                     if k != "label"}
            self._buf[tid].append(piece)
            self._buffered[tid] += take
        dropped = n - take
        if dropped:
            self._counters[tid]["pkts_dropped"] += dropped
        return take

    def _pop(self, tid: int, size: int) -> Dict[str, np.ndarray]:
        """Pop exactly ``size`` packets from the front of the queue
        (splitting a buffered piece when the boundary lands inside it)."""
        buf = self._buf[tid]
        parts, got = [], 0
        while got < size:
            piece = buf.popleft()
            n = len(piece["ts"])
            if got + n > size:
                cut = size - got
                parts.append({k: v[:cut] for k, v in piece.items()})
                buf.appendleft({k: v[cut:] for k, v in piece.items()})
                got = size
            else:
                parts.append(piece)
                got += n
        self._buffered[tid] -= size
        if len(parts) == 1:
            return parts[0]
        return {k: np.concatenate([p[k] for p in parts]) for k in parts[0]}

    # ------------------------------------------------------------------
    # continuous batching
    # ------------------------------------------------------------------
    def _tenant_step(self):
        from repro.serving.fused import make_tenant_step
        return make_tenant_step(backend=self.backend, mode=self.mode,
                                backend_kw=self.backend_kw,
                                md_backend=self.md_backend, md_kw=self.md_kw,
                                epoch=self.epoch)

    def _dispatch(self, tids: List[int], size: int) -> None:
        """Pack one chunk from each tenant in ``tids`` into a single
        tenant-batched fused call.  Returns immediately with the batch in
        flight; ``self.pool.stacked`` is donated and replaced."""
        chunks = [self._pop(t, size) for t in tids]
        if self.state_backend == "dense":
            # dense-mode aliasing telemetry: distinct flow keys whose slots
            # collide inside this chunk (host-side numpy twin of the device
            # hash, so the fused call is untouched).  Sketch pools absorb
            # collisions by design and keep the counter at zero.
            for t, c in zip(tids, chunks):
                self._counters[t]["slot_collisions"] += \
                    slot_collisions(c, self.n_slots)["total"]
        pk = {k: jnp.asarray(np.stack([c[k] for c in chunks]))
              for k in chunks[0]}
        ids = jnp.asarray(np.asarray(tids, np.int32))
        base_mods = jnp.asarray(np.asarray(
            [self._pkt_count[t] % self.epoch for t in tids], np.int32))
        t0 = time.perf_counter()
        out = self._tenant_step()(self.pool.stacked, ids, self.net,
                                  np.float32(self.threshold), base_mods, pk)
        self.pool.stacked = out[0]
        self.pool.mark_dirty(tids)
        bases = [self._pkt_count[t] for t in tids]
        for t in tids:
            self._pkt_count[t] += size
        if self._t_first is None:
            self._t_first = t0
        self._inflight.append((tids, bases, out[1:], t0, size))

    def _drain_one(self) -> None:
        """Block on the OLDEST in-flight batch; only the O(records)
        sampled outputs cross to the host."""
        tids, bases, (idx, scores, alarms, counts), t0, size = \
            self._inflight.popleft()
        idx, scores = np.asarray(idx), np.asarray(scores)
        alarms, counts = np.asarray(alarms), np.asarray(counts)
        now = time.perf_counter()
        self._t_last = now
        for lane, tid in enumerate(tids):
            c = int(counts[lane])
            gi = idx[lane, :c].astype(np.int64) + bases[lane]
            sc, al = scores[lane, :c], alarms[lane, :c]
            acc = self._results[tid]
            acc[0].append(gi)
            acc[1].append(sc)
            acc[2].append(al)
            self._lat[tid].append(now - t0)
            cnt = self._counters[tid]
            cnt["pkts_processed"] += size
            cnt["records"] += c
            n_al = int(al.sum())
            cnt["alarms"] += n_al
            if n_al and self.alarm_dir is not None:
                self._log_alarms(tid, gi[al], sc[al])
        self._pkts_done += size * len(tids)

    def _drain_all(self) -> None:
        while self._inflight:
            self._drain_one()

    def step(self) -> int:
        """One engine tick: drain every READY tenant (a full chunk
        buffered) into tenant-batched fused calls, at most ``max_batch``
        lanes per call, dispatching each batch before the previous one is
        drained.  Returns the number of batches dispatched."""
        dispatched = 0
        while True:
            ready = [t for t in self.pool.live
                     if self._buffered.get(t, 0) >= self.chunk]
            if not ready:
                break
            for i in range(0, len(ready), self.max_batch):
                self._dispatch(ready[i:i + self.max_batch], self.chunk)
                dispatched += 1
                while len(self._inflight) > 1:   # keep ONE batch in flight
                    self._drain_one()
        return dispatched

    def flush(self) -> None:
        """Drain everything: remaining full chunks, then partial tails
        (tenants with equal tail length share a batch), then every
        in-flight batch.  After ``flush`` all submitted-and-accepted
        packets are reflected in ``results``."""
        self.step()
        tails: Dict[int, List[int]] = {}
        for t in self.pool.live:
            n = self._buffered.get(t, 0)
            if n:
                tails.setdefault(n, []).append(t)
        for size, tids in sorted(tails.items()):
            for i in range(0, len(tids), self.max_batch):
                self._dispatch(tids[i:i + self.max_batch], size)
                while len(self._inflight) > 1:   # keep ONE batch in flight
                    self._drain_one()
        self._drain_all()

    # ------------------------------------------------------------------
    # results / telemetry / alarm delivery
    # ------------------------------------------------------------------
    def results(self, tid: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Concatenated (global_record_indices, scores, alarms) drained so
        far for tenant ``tid`` — the same triple ``process_stream``
        returns."""
        gi, sc, al = self._results[tid]
        if not gi:
            return (np.zeros((0,), np.int64), np.zeros((0,), np.float32),
                    np.zeros((0,), bool))
        return np.concatenate(gi), np.concatenate(sc), np.concatenate(al)

    def stats(self) -> Dict:
        """Operational counters: per-tenant ingress/drop/record/alarm
        counts and p50/p99 per-chunk latency (ms), plus aggregate
        processed-packet count and pps over the dispatch→drain window."""
        per = {}
        for tid in self._counters:
            lat = np.asarray(self._lat[tid]) * 1e3
            per[tid] = dict(self._counters[tid])
            per[tid]["p50_ms"] = float(np.percentile(lat, 50)) if len(lat) else 0.0
            per[tid]["p99_ms"] = float(np.percentile(lat, 99)) if len(lat) else 0.0
        wall = ((self._t_last - self._t_first)
                if self._t_first is not None and self._t_last is not None
                else 0.0)
        return {"tenants": per,
                "aggregate": {"pkts_processed": self._pkts_done,
                              "wall_s": wall,
                              "pps": self._pkts_done / wall if wall else 0.0}}

    def _log_alarms(self, tid: int, gi: np.ndarray, sc: np.ndarray) -> None:
        f = self._alarm_files.get(tid)
        if f is None:
            os.makedirs(self.alarm_dir, exist_ok=True)
            path = os.path.join(self.alarm_dir,
                                f"tenant{tid}.{self.alarm_format}")
            f = open(path, "a")
            if self.alarm_format == "csv" and f.tell() == 0:
                f.write("tenant,record_index,score\n")
            self._alarm_files[tid] = f
        if self.alarm_format == "csv":
            f.writelines(f"{tid},{i},{s}\n" for i, s in zip(gi, sc))
        else:
            f.writelines(json.dumps({"tenant": tid, "record": int(i),
                                     "score": float(s)}) + "\n"
                         for i, s in zip(gi, sc))
        f.flush()

    def close(self) -> None:
        for f in self._alarm_files.values():
            f.close()
        self._alarm_files.clear()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------
    # convenience driver
    # ------------------------------------------------------------------
    def run(self, traces: Dict[int, Dict[str, np.ndarray]],
            feed: Optional[int] = None) -> Dict[int, Tuple]:
        """Feed whole traces through the engine, respecting backpressure
        (the driver pauses a tenant's feed instead of shedding), and run
        to completion: round-robin submit → tick → flush.  Returns
        ``{tid: (indices, scores, alarms)}``.  The deployment entry points
        remain ``submit``/``step``/``flush``; this is the offline/benchmark
        driver shape."""
        feed = self.chunk if feed is None else int(feed)
        cursors = {t: 0 for t in traces}
        total = {t: len(tr["ts"]) for t, tr in traces.items()}
        while True:
            moved = False
            for t, tr in traces.items():
                if cursors[t] >= total[t]:
                    continue
                take = min(feed, total[t] - cursors[t], self.room(t))
                if take:
                    piece = {k: v[cursors[t]:cursors[t] + take]
                             for k, v in tr.items()}
                    self.submit(t, piece)
                    cursors[t] += take
                    moved = True
            self.step()
            if not moved and all(cursors[t] >= total[t] for t in traces):
                break
        self.flush()
        return {t: self.results(t) for t in traces}
