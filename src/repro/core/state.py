"""Flow-state tables — the TPU analogue of the switch's register arrays.

Slots are direct-indexed by ``hash(flow_key) % n_slots`` with *no* collision
resolution, exactly like the switch's stateful SRAM arrays (colliding flows
merge — part of the fidelity model, noted in DESIGN.md §1).

Four decay instances per atom (lambda = 10, 1, 1/10, 1/60 — windows 100ms /
1s / 10s / 60s) as in §4.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

LAMBDAS = (10.0, 1.0, 0.1, 1.0 / 60.0)
N_DECAY = len(LAMBDAS)

# key types
UNI_KEYS = ("src_mac_ip", "src_ip")            # unidirectional stats
BI_KEYS = ("channel", "socket")                # bidirectional stats
N_UNI, N_BI = len(UNI_KEYS), len(BI_KEYS)

UNI_STATS = ("w", "mean", "std")
BI_STATS = ("w", "mean", "std", "magnitude", "radius", "cov", "pcc")
N_FEATURES = N_UNI * N_DECAY * len(UNI_STATS) + N_BI * N_DECAY * len(BI_STATS)

FEATURE_NAMES = tuple(
    f"{k}:{lam}:{s}"
    for k in UNI_KEYS for lam in LAMBDAS for s in UNI_STATS
) + tuple(
    f"{k}:{lam}:{s}"
    for k in BI_KEYS for lam in LAMBDAS for s in BI_STATS
)


def init_state(n_slots: int) -> Dict:
    """Fresh flow tables. Shapes:

    uni tables: (N_UNI, n_slots, N_DECAY) atoms; bi tables carry a direction
    axis (N_BI, n_slots, 2, N_DECAY) plus channel-level SR state.
    """
    z = jnp.zeros
    return {
        "uni": {
            "last_t": z((N_UNI, n_slots, N_DECAY)) - 1.0,
            "w": z((N_UNI, n_slots, N_DECAY)),
            "ls": z((N_UNI, n_slots, N_DECAY)),
            "ss": z((N_UNI, n_slots, N_DECAY)),
            "rr": z((N_UNI, n_slots), jnp.int32),
        },
        "bi": {
            "last_t": z((N_BI, n_slots, 2, N_DECAY)) - 1.0,
            "w": z((N_BI, n_slots, 2, N_DECAY)),
            "ls": z((N_BI, n_slots, 2, N_DECAY)),
            "ss": z((N_BI, n_slots, 2, N_DECAY)),
            "sr": z((N_BI, n_slots, N_DECAY)),
            "sr_last_t": z((N_BI, n_slots, N_DECAY)) - 1.0,
            "res_last": z((N_BI, n_slots, 2, N_DECAY)),
            "rr": z((N_BI, n_slots), jnp.int32),
        },
    }


def state_slots(state: Dict) -> int:
    """Static slot count, derived from table shapes (jit-safe)."""
    return state["uni"]["w"].shape[1]


# ---------------------------------------------------------------------------
# Flow-key hashing (CRC-like mix, vectorised)
# ---------------------------------------------------------------------------
def _mix(h: jax.Array, v: jax.Array) -> jax.Array:
    h = (h ^ v) * jnp.uint32(0x9E3779B1)
    return h ^ (h >> 15)


def hash_fields(fields, salt: int) -> jax.Array:
    h = jnp.full(fields[0].shape, jnp.uint32(salt ^ 0x811C9DC5))
    for f in fields:
        h = _mix(h, f.astype(jnp.uint32))
    return h


def packet_slots(pkts: Dict[str, jax.Array], n_slots: int) -> Dict[str, jax.Array]:
    """Per-packet slot indices + channel direction bit.

    pkts: {ts, src, dst, sport, dport, proto, length} arrays of shape (n,).
    Channel/socket keys are canonicalised (min/max endpoint) so both
    directions land in the same slot; ``dir`` = 0 if src is the canonical
    low endpoint else 1.  Equal IPs (same-host/loopback socket pairs) break
    the tie on ports, so the two directions of a swapped-port socket still
    share a slot with opposite ``dir`` bits instead of merging.
    """
    src, dst = pkts["src"], pkts["dst"]
    sport, dport = pkts["sport"], pkts["dport"]
    lo_is_src = (src < dst) | ((src == dst) & (sport <= dport))
    ip_lo = jnp.where(lo_is_src, src, dst)
    ip_hi = jnp.where(lo_is_src, dst, src)
    p_lo = jnp.where(lo_is_src, sport, dport)
    p_hi = jnp.where(lo_is_src, dport, sport)
    ns = jnp.uint32(n_slots)
    return {
        "src_mac_ip": (hash_fields((src,), 1) % ns).astype(jnp.int32),
        "src_ip": (hash_fields((src,), 2) % ns).astype(jnp.int32),
        "channel": (hash_fields((ip_lo, ip_hi), 3) % ns).astype(jnp.int32),
        "socket": (hash_fields((ip_lo, ip_hi, p_lo, p_hi, pkts["proto"]), 4)
                   % ns).astype(jnp.int32),
        "dir": (~lo_is_src).astype(jnp.int32),
    }
