"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.state import LAMBDAS, N_DECAY


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q: (B,H,Sq,D); k/v: (B,K,Sk,D). Full-score fp32 softmax attention."""
    B, H, Sq, D = q.shape
    K, Sk = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, Sq, D).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bktd->bkgqt", qg,
                   k.astype(jnp.float32)) / math.sqrt(D)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= qpos >= kpos
    if window > 0:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,bktd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)


def feature_update_ref(table, slots, ts, lens):
    """Serial oracle for the single-key streaming atom update (exact mode)."""
    lam = jnp.asarray(LAMBDAS, jnp.float32)

    def step(tab, pkt):
        slot, t, x = pkt
        lt = tab["last_t"][slot]
        fresh = lt < 0
        delta = jnp.where(fresh, 0.0, jnp.exp2(-lam * jnp.maximum(t - lt, 0)))
        w2 = tab["w"][slot] * delta + 1.0
        ls2 = tab["ls"][slot] * delta + x
        ss2 = tab["ss"][slot] * delta + x * x
        mu = ls2 / w2
        sig = jnp.sqrt(jnp.abs(ss2 / w2 - mu * mu))
        tab = {
            "last_t": tab["last_t"].at[slot].set(t),
            "w": tab["w"].at[slot].set(w2),
            "ls": tab["ls"].at[slot].set(ls2),
            "ss": tab["ss"].at[slot].set(ss2),
        }
        return tab, jnp.concatenate([w2, mu, sig])

    table, stats = jax.lax.scan(step, table, (slots, ts, lens))
    return table, stats


def kitnet_ensemble_ref(x_sub, w1, b1, w2, b2, mask):
    """x_sub: (B,k,m) -> per-AE RMSE (B,k)."""
    xm = x_sub * mask[None]
    h = jax.nn.sigmoid(jnp.einsum("bkm,kmh->bkh", xm, w1) + b1[None])
    y = jax.nn.sigmoid(jnp.einsum("bkh,khm->bkm", h, w2) + b2[None])
    se = ((y - xm) ** 2) * mask[None]
    denom = jnp.maximum(mask.sum(-1), 1.0)
    return jnp.sqrt(se.sum(-1) / denom[None])
