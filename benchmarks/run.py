"""Benchmark orchestrator — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time of the
bench's core measured operation; derived = the headline metric it produces).

Full-size variants of each bench are runnable standalone, e.g.
  PYTHONPATH=src python -m benchmarks.detection_auc          (Fig 7, full)
  PYTHONPATH=src python -m benchmarks.roofline               (§Roofline)
"""
from __future__ import annotations

import contextlib
import io
import sys
import time


def _bench(name, fn):
    t0 = time.perf_counter()
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        derived = fn()
    us = (time.perf_counter() - t0) * 1e6
    print(f"{name},{us:.0f},{derived}")
    return buf.getvalue()


def bench_detection_auc():
    """Fig 1/7 + Fig 14/15 (quick subset)."""
    from benchmarks.detection_auc import QUICK_RATES, run, summarize
    table = run(("syn_dos", "ssdp_flood", "mirai"), QUICK_RATES,
                n_train=8000, n_eval=12000, mode="switch")
    head = summarize(table, QUICK_RATES)
    p = head["peregrine"]["auc>0.8_all_sampled_rates"]
    k = head["kitsune"]["auc>0.8_all_sampled_rates"]
    return f"peregrine_effective={p}/3;kitsune_effective={k}/3"


def bench_throughput():
    """Fig 8."""
    from benchmarks.throughput import fc_rates, md_rate
    fc = fc_rates(n_pkts=8000,
                  backends=("serial", "scan", "pallas", "sharded:4"))
    md = md_rate(n_train=2000, n_score=4096)
    return (f"fc_scan_pps={fc['scan_pps']:.0f};"
            f"fc_sharded4_pps={fc['sharded4_pps']:.0f};"
            f"md_rps={md:.0f}")


def bench_pipeline_split():
    """Fig 9/10."""
    from benchmarks.pipeline_split import split_for
    r = split_for("syn_dos", 6000)
    return (f"fc_share={r['fc_share'] * 100:.0f}%;"
            f"offload_speedup={r['offload_speedup']:.2f}x")


def bench_resource_usage():
    """Table 3."""
    from benchmarks.resource_usage import state_bytes
    r = state_bytes(65536)
    return f"state_bytes_64k_slots={r['total_bytes']}"


def bench_cost_model():
    """Fig 11/12."""
    from benchmarks.cost_model import SERVER_COST, SERVER_GBPS, SERVER_W, \
        SWITCH_COST, SWITCH_W
    import numpy as np
    g = 6400
    n = int(np.ceil(g / SERVER_GBPS))
    ratio = n * SERVER_COST / (SWITCH_COST + SERVER_COST)
    return f"cost_ratio_at_6.4T={ratio:.0f}x"


def bench_approx_ablation():
    """§5.4 approximation ablation (single attack)."""
    from repro.detection.sweep import sweep_attack
    from repro.traffic import synth_trace
    data = synth_trace("ssdp_flood", n_train=6000, n_benign_eval=3000,
                       n_attack=3000, seed=11)
    ex = sweep_attack(data, [64], mode="exact")["peregrine"][64]["auc"]
    sw = sweep_attack(data, [64], mode="switch")["peregrine"][64]["auc"]
    return f"auc_exact={ex:.3f};auc_switch={sw:.3f}"


def bench_roofline():
    """§Roofline from the dry-run artifacts (if present)."""
    from benchmarks.roofline import analyse, load_records
    recs = load_records()
    if not recs:
        return "no_dryrun_artifacts(run repro.launch.dryrun)"
    rows = [a for a in (analyse(r) for r in recs) if a]
    import os, json
    from benchmarks.common import RESULTS
    with open(os.path.join(RESULTS, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1)
    dom = {}
    for r in rows:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    return f"cells={len(rows)};dominant={dom}"


def main() -> None:
    print("name,us_per_call,derived")
    _bench("detection_auc_fig7", bench_detection_auc)
    _bench("throughput_fig8", bench_throughput)
    _bench("pipeline_split_fig9_10", bench_pipeline_split)
    _bench("resource_usage_table3", bench_resource_usage)
    _bench("cost_model_fig11_12", bench_cost_model)
    _bench("approx_ablation_s54", bench_approx_ablation)
    _bench("roofline_terms", bench_roofline)


if __name__ == '__main__':
    main()
