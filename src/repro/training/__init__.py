from repro.training.optim import make_optimizer  # noqa: F401
from repro.training.train_step import make_train_step, init_train_state  # noqa: F401
from repro.training.checkpoint import CheckpointManager  # noqa: F401
from repro.training.rematctx import use_remat, current_remat  # noqa: F401
