"""Config registry: one module per assigned architecture (+ Peregrine's own).

``get_arch(name)`` resolves an architecture id (e.g. "gemma2-2b") to its
:class:`ArchConfig`; ``ARCHS`` lists all assigned ids.
"""
from __future__ import annotations

from repro.configs.base import (  # noqa: F401
    ArchConfig, ShapeConfig, TrainConfig, SHAPES, TRAIN_4K, PREFILL_32K,
    DECODE_32K, LONG_500K, reduced,
)
from repro.configs import (  # noqa: F401
    phi35_moe, kimi_k2, zamba2, granite_20b, gemma2_2b, deepseek_7b,
    starcoder2_15b, hubert_xlarge, qwen2_vl_72b, xlstm_125m,
)

_MODULES = {
    "phi3.5-moe-42b-a6.6b": phi35_moe,
    "kimi-k2-1t-a32b": kimi_k2,
    "zamba2-2.7b": zamba2,
    "granite-20b": granite_20b,
    "gemma2-2b": gemma2_2b,
    "deepseek-7b": deepseek_7b,
    "starcoder2-15b": starcoder2_15b,
    "hubert-xlarge": hubert_xlarge,
    "qwen2-vl-72b": qwen2_vl_72b,
    "xlstm-125m": xlstm_125m,
}

ARCHS = tuple(_MODULES)


def get_arch(name: str) -> ArchConfig:
    return _MODULES[name].CONFIG


def arch_cells():
    """Yield every (arch, shape) cell with its skip status + reason."""
    from repro.configs.base import SHAPES
    for name, mod in _MODULES.items():
        cfg = mod.CONFIG
        for sname, shape in SHAPES.items():
            skip = skip_reason(cfg, shape)
            yield name, sname, skip


def skip_reason(cfg: ArchConfig, shape: ShapeConfig):
    """None if runnable, else a human-readable skip reason (DESIGN.md §4)."""
    if cfg.is_encoder and shape.kind == "decode":
        return "encoder-only arch: no decode step"
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return "full-attention arch: O(S^2) at 524k; sub-quadratic required"
    return None
