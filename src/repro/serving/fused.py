"""Device-resident fused serving step: fc → epoch gather → MD in ONE jit.

The staged ``DetectionService.process`` path round-trips to the host twice
per chunk: the full (n, 80) feature matrix is pulled off device to run
numpy epoch sampling, then the sampled records are pushed back for KitNET
scoring.  On the measured host that throws away roughly two thirds of the
scan backend's FC throughput (benchmarks/results/throughput.json) — the
same CPU-cycle waste Peregrine's offloading exists to eliminate.

This module compiles the whole per-chunk pipeline as one donated jit:

    state, idx, scores, alarms, count = step(state, net, thr, base_mod, pkts)

* ``state`` is **donated** (``donate_argnums``) and carried on device — the
  flow tables never migrate, and the caller must treat the handle it passed
  in as consumed (DESIGN.md §8 records the contract).
* Epoch sampling runs as a jit-safe on-device gather
  (``repro.core.records.epoch_gather``): fixed-size index vector + valid
  count, so sampling stays inside the fused computation.
* FC runs through ``compute_features_sampled``: backends with a native
  record-sampled path (``scan``, ``bucketed``) update flow state for every
  packet but
  materialise feature statistics only at the sampled rows — sampling still
  happens *after* feature computation (the paper's architectural move),
  the unsampled rows just never leave the segmented scans.
* Only the sampled ``(idx, scores, alarms, count)`` ever cross to the host
  — never the (n, 80) feature matrix — and they cross *asynchronously*:
  the step returns device futures, so ``DetectionService.process_stream``
  can dispatch chunk k+1 before chunk k's results are drained.

Works with any registered FC backend (exact mode) × any MD backend; the
parity suite (tests/test_fused.py) holds serial-semantics FC backends to
bit-identical staged-vs-fused outputs.

The same per-chunk core serves two deployment shapes (DESIGN.md §10): the
single-stream ``DetectionService`` jits it directly (``make_fused_step``),
and the multi-tenant ``DetectionEngine`` vmaps it over a tenant axis
(``make_tenant_step``) — T tenants' chunks gathered from a stacked state
pool, advanced in ONE donated jit, and scattered back, tenant ids carried
with every lane so states and epoch counters never mix.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.backends import compute_features_sampled, resolve_backend
from repro.core.records import epoch_gather
from repro.detection.md_backends import md_score_fn
from repro.distributed.sharding import (ambient_mesh, flow_shards_binding,
                                        tenant_binding)


def _freeze(kw: Dict) -> Tuple:
    return tuple(sorted(kw.items()))


def _placement_token():
    """Ambient placement (mesh + ``flow_shards``/``tenants`` rules +
    device count).

    Part of the fused-step cache key: the partitioned FC backends
    (``bucketed``/``sharded``) resolve their mesh placement at trace time,
    so binding or unbinding a mesh must hand back a *different* step —
    otherwise the cached executable silently keeps the placement it was
    first traced under (the exact hazard ``core/bucketed.py`` resolves
    outside jit to avoid).  Shares the binding lookups with that resolver
    (``distributed/sharding``) so key and trace can never disagree.  The
    device count is in the token explicitly so a mesh re-bound under a
    different forced-device topology can never be served a stale step."""
    return (flow_shards_binding(), tenant_binding(), ambient_mesh(),
            jax.device_count())


def _tenant_sharding(placement: Tuple):
    """``NamedSharding`` spreading the tenant (leading) axis of the
    tenant-batched step over the ambient ``tenants`` rule, or ``None``
    when unplaced (no mesh, no rule, or the rule names axes the mesh
    lacks).  Resolved from the placement token at step-build time — the
    same values that key the cache — so the constraint and the cache can
    never disagree."""
    _, tenants, mesh, _ = placement
    if mesh is None or tenants is None:
        return None
    axes = tenants if isinstance(tenants, tuple) else (tenants,)
    if not all(a in mesh.axis_names for a in axes):
        return None
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(mesh, P(tenants))


def _make_core(backend: str, mode: str, backend_kw: Tuple,
               md_backend: str, md_kw: Tuple, epoch: int) -> Callable:
    """The SHARED per-chunk step: FC → on-device epoch gather → KitNET →
    threshold, state carried through.  Pure and traceable — the
    single-stream service jits it donated (``make_fused_step``) and the
    multi-tenant engine vmaps it over a tenant axis (``make_tenant_step``);
    both deployment shapes run the identical computation."""
    fc_kw = dict(backend_kw)
    score = md_score_fn(md_backend, **dict(md_kw))

    def step(state, net, threshold, base_mod, pkts):
        idx, count = epoch_gather(pkts["ts"].shape[0], epoch, base_mod)
        # record-sampled FC: the flow-table update covers every packet,
        # but feature rows are only materialised at the epoch boundaries —
        # sampling happens AFTER feature computation (the paper's move),
        # yet unsampled packets never pay the statistics-assembly cost
        state, recs = compute_features_sampled(state, pkts, idx,
                                               backend=backend, mode=mode,
                                               **fc_kw)
        scores = score(net, recs)
        return state, idx, scores, scores > threshold, count

    return step


@functools.lru_cache(maxsize=None)
def _cached_step(backend: str, mode: str, backend_kw: Tuple,
                 md_backend: str, md_kw: Tuple, epoch: int,
                 placement: Tuple = (None, None, None, 1)) -> Callable:
    step = _make_core(backend, mode, backend_kw, md_backend, md_kw, epoch)
    return jax.jit(step, donate_argnums=(0,))


@functools.lru_cache(maxsize=None)
def _cached_tenant_step(backend: str, mode: str, backend_kw: Tuple,
                        md_backend: str, md_kw: Tuple, epoch: int,
                        placement: Tuple = (None, None, None, 1)) -> Callable:
    core = _make_core(backend, mode, backend_kw, md_backend, md_kw, epoch)
    # net and threshold are shared across tenants (one fitted detector,
    # many streams); state / epoch residue / packets carry the tenant axis
    vcore = jax.vmap(core, in_axes=(0, None, None, 0, 0))
    lane_sharding = _tenant_sharding(placement)

    def constrain(tree):
        # spread the tenant (leading) axis over the ``tenants`` mesh rule:
        # each device advances its lanes' FC scans + KitNET independently
        # (lanes share nothing but net/threshold, which XLA replicates).
        # A lane count that does not divide the axis still compiles — XLA
        # pads the partition — so ragged final batches stay placed.
        return jax.tree_util.tree_map(
            lambda x: jax.lax.with_sharding_constraint(x, lane_sharding),
            tree)

    def step(pool, tenant_ids, net, threshold, base_mods, pkts):
        sub = jax.tree_util.tree_map(lambda x: x[tenant_ids], pool)
        if lane_sharding is not None:
            sub, base_mods, pkts = (constrain(sub), constrain(base_mods),
                                    constrain(pkts))
        sub, idx, scores, alarms, counts = vcore(sub, net, threshold,
                                                 base_mods, pkts)
        pool = jax.tree_util.tree_map(
            lambda p, s: p.at[tenant_ids].set(s), pool, sub)
        return pool, idx, scores, alarms, counts

    return jax.jit(step, donate_argnums=(0,))


def make_fused_step(backend: str = "scan", mode: str = "exact",
                    backend_kw: Dict = None, md_backend: str = "einsum",
                    md_kw: Dict = None, epoch: int = 1024) -> Callable:
    """Build (or fetch from cache) the fused per-chunk step.

    Returns ``step(state, net, threshold, base_mod, pkts)`` →
    ``(new_state, idx, scores, alarms, count)`` where every output is a
    device array: ``idx`` (ceil(n/epoch),) int32 within-chunk record
    positions zero-padded past ``count``; ``scores``/``alarms`` aligned
    with ``idx`` (rows past ``count`` are padding garbage — slice by the
    count before use).  ``base_mod`` is the running packet count modulo
    ``epoch`` (traced, so chunk position never forces a recompile).

    **Donation contract:** the ``state`` argument is donated — its buffers
    are invalidated by the call.  Never reuse the passed-in handle; always
    continue from the returned state, and snapshot with
    ``jax.tree_util.tree_map(jnp.copy, state)`` (an aliasing ``tree_map``
    of the identity keeps the doomed buffers).
    """
    return _cached_step(resolve_backend(backend), mode,
                        _freeze(backend_kw or {}), md_backend,
                        _freeze(md_kw or {}), epoch,
                        placement=_placement_token())


def make_tenant_step(backend: str = "scan", mode: str = "exact",
                     backend_kw: Dict = None, md_backend: str = "einsum",
                     md_kw: Dict = None, epoch: int = 1024) -> Callable:
    """Build (or fetch from cache) the TENANT-BATCHED fused step.

    Returns ``step(pool, tenant_ids, net, threshold, base_mods, pkts)`` →
    ``(new_pool, idx, scores, alarms, counts)``: the per-chunk core of
    :func:`make_fused_step` vmapped over a leading tenant axis.  ``pool``
    is a stacked state pytree (``core.state.init_state_stacked`` /
    ``StatePool.stacked``), ``tenant_ids`` a ``(T,)`` int32 vector of pool
    slots (traced — changing WHICH tenants ride a batch never recompiles;
    changing how MANY does), ``base_mods`` the ``(T,)`` per-tenant epoch
    residues, and ``pkts`` packet arrays stacked to ``(T, chunk)``.  Tenant
    states are gathered from the pool, advanced independently (per-lane
    results are bitwise those of the single-tenant step on this host —
    tests/test_engine.py pins it), and scattered back inside the same jit,
    so states and epoch counters cannot mix.  ``net``/``threshold`` are
    shared: one fitted detector serving many streams.

    When a mesh is bound and the ``tenants`` logical axis has a rule
    (e.g. under ``distributed.sharding.flow_mesh``), the tenant axis of
    the gathered lanes is sharded over that rule — tenant lanes advance
    device-parallel, the engine's first mesh placement (DESIGN.md §12).
    The placement participates in the step cache key exactly like the
    flow-table placement.

    **Donation contract (DESIGN.md §8, unchanged):** ``pool`` is donated —
    continue from the returned pool only; ``tenant_ids`` must not repeat a
    tenant within one call (its state would be gathered once and scattered
    last-write-wins).
    """
    return _cached_tenant_step(resolve_backend(backend), mode,
                               _freeze(backend_kw or {}), md_backend,
                               _freeze(md_kw or {}), epoch,
                               placement=_placement_token())
