"""Figure 8 analog: system throughput vs sampling rate.

The paper measures 100G-link packet rates against the ML classifier's
record-processing rate, binary-searching the highest stable rate.  Offline
(CPU-only) we measure the two component rates directly and derive the same
curve:

    stable_pps(rate) = min(FC_pps, MD_records_per_s * rate)

FC_pps is measured per backend through the unified
``repro.core.backends.compute_features`` API in *streaming steady state*:
the trace is cut into fixed-size chunks and fed through the backend with
flow-table state carried across chunk boundaries (exactly what
``DetectionService.process_stream`` does in deployment), timed after a full
warm-up pass.  Any registered backend can be benchmarked by name
(``--backends serial,scan,pallas,sharded:4,sharded:16`` — ``sharded:S``
selects the partition count):

  * serial  — the per-packet oracle (lax.scan), exact arithmetic;
  * scan    — TPU-native segmented-scan pipeline;
  * pallas  — the full-feature Pallas kernel (interpret mode on CPU; on TPU
    this is the line-rate path);
  * sharded — hash-partitioned flow tables, S shards vmapped (or placed on
    a mesh); serial per-packet semantics inside each shard.

``--stage full`` additionally measures the WHOLE pipeline — FC -> per-epoch
record sampling -> per-chunk MD scoring — for every (fc_backend x
md_backend) pair through ``DetectionService.process_stream``, emitting
``pipeline_<fc>_x_<md>_pps`` rows into ``results/throughput.json`` next to
the FC-only rows.  MD backends (``--md-backends einsum,pallas``) come from
``repro.detection.md_backends`` — the batched einsum path or the fused
Pallas ensemble kernel (DESIGN.md §3).

The TPU projection for the scan pipeline is derived from its roofline bytes
(see EXPERIMENTS.md §Perf — Peregrine pipeline).

Note on sharded-vs-scan on this host: the sharded backend keeps the serial
oracle's per-packet scan *inside* each shard, and every shard scans the
full packet batch (non-members are redirected to a discarded scratch row),
so on ONE device it does ~S× the serial oracle's work on the same
n-sequential-step critical path — expect ``sharded`` to land in
``serial``'s speed class (per-step dispatch overhead hides the S× work at
small S; large S drops below serial) and far below ``scan``.  Its win is
capacity/placement, not single-host pps: S× flow slots spread over mesh
devices (the ``flow_shards`` axis), each device holding 1/S of the state
in fast memory and doing 1/S of the member updates — the switch's
partitioned SRAM, TPU VMEM.  All backends are measured in ``exact`` mode
so the serial/sharded/scan rates are directly comparable; the benchmark
records them so the crossover can be re-checked on real multi-device
hardware.
"""
from __future__ import annotations

import argparse
from typing import Dict, Tuple

import jax

from benchmarks.common import save, timeit
from repro.core import (available_backends, compute_features, init_state,
                        resolve_backend)
from repro.detection.kitnet import score_kitnet, train_kitnet
from repro.detection.md_backends import (available_md_backends,
                                         validate_md_options)
from repro.serving import DetectionService
from repro.traffic import synth_trace, to_jnp

import numpy as np

# the serial-semantics backends are orders of magnitude slower per packet:
# measure them on a truncated stream so the benchmark finishes
_BACKEND_PKTS = {"serial": 2000, "sharded": 2000, "scan": None, "pallas": 4096}

DEFAULT_BACKENDS = "serial,scan,pallas,sharded:4,sharded:16"


def parse_backend(spec: str) -> Tuple[str, Dict, str]:
    """``"sharded:16"`` -> (name, backend kwargs, result label)."""
    if ":" in spec:
        name, arg = spec.split(":", 1)
        name = resolve_backend(name)
        if name != "sharded":
            raise ValueError(f"only sharded takes a :S suffix, got {spec!r}")
        return name, {"shards": int(arg)}, f"sharded{arg}"
    return resolve_backend(spec), {}, resolve_backend(spec)


def fc_rates(n_pkts: int = 20000, n_slots: int = 8192,
             backends=tuple(DEFAULT_BACKENDS.split(",")),
             chunk: int = 2048) -> Dict[str, float]:
    """Steady-state streaming FC rate per backend: fixed-size chunks with
    flow-table state carried across chunk boundaries."""
    data = synth_trace("mirai", n_train=n_pkts, n_benign_eval=1000,
                       n_attack=1000, seed=0)
    pk = to_jnp(data["train"])

    out = {}
    for spec in backends:
        name, kw, label = parse_backend(spec.strip())
        cap = _BACKEND_PKTS.get(name)
        n = n_pkts if cap is None else min(cap, n_pkts)
        c = min(chunk, n)
        n = (n // c) * c                    # equal-size chunks: one compile
        chunks = [{k: v[i:i + c] for k, v in pk.items()}
                  for i in range(0, n, c)]

        def stream(state):
            f = None
            for ch in chunks:
                state, f = compute_features(state, ch, backend=name,
                                            mode="exact", **kw)
            jax.block_until_ready(f)
            return state

        warm = stream(init_state(n_slots))  # compile + steady-state tables
        reps = 3 if name == "scan" else 1
        t = timeit(lambda: stream(warm), reps=reps, warmup=0)
        out[f"{label}_pps"] = n / t
    return out


def service_rate(n_pkts: int = 8000, epoch: int = 256,
                 chunk: int = 2048) -> float:
    """End-to-end ``DetectionService.process_stream`` packet rate (FC +
    record sampling + KitNET scoring) on the default batch backend."""
    data = synth_trace("mirai", n_train=n_pkts, n_benign_eval=n_pkts // 2,
                       n_attack=n_pkts // 2, seed=0)
    svc = DetectionService(epoch=epoch, n_slots=8192, mode="exact")
    svc.observe_stream(data["train"], chunk=chunk)
    svc.fit()
    n_eval = len(data["eval"]["ts"])
    svc.process_stream(data["eval"], chunk=chunk)       # warm-up/compile
    t = timeit(lambda: svc.process_stream(data["eval"], chunk=chunk),
               reps=3, warmup=0)
    return n_eval / t


def md_rate(n_train: int = 4000, n_score: int = 8192):
    rng = np.random.default_rng(0)
    feats = rng.random((n_train, 80)).astype(np.float32)
    net = train_kitnet(feats, seed=0)
    batch = rng.random((n_score, 80)).astype(np.float32)
    t = timeit(lambda: score_kitnet(net, batch), reps=3)
    return n_score / t


def pipeline_rates(backends, md_backends=("einsum", "pallas"),
                   n_pkts: int = 8000, epoch: int = 64, n_slots: int = 8192,
                   chunk: int = 2048) -> Dict[str, float]:
    """``--stage full``: steady-state pps of the WHOLE pipeline — FC ->
    per-epoch record sampling -> per-chunk MD scoring — for every
    (fc_backend x md_backend) pair, measured through
    ``DetectionService.process_stream`` exactly as deployed (state + packet
    count carried across chunks, scores emitted per chunk).  ``epoch=64``
    keeps the MD stage on ~1/64 of the packets so its cost is visible in
    the pair rates rather than rounding away."""
    data = synth_trace("mirai", n_train=n_pkts, n_benign_eval=n_pkts // 2,
                       n_attack=n_pkts // 2, seed=0)
    out = {}
    for spec in backends:
        name, kw, label = parse_backend(spec.strip())
        cap = _BACKEND_PKTS.get(name)
        ntr = n_pkts if cap is None else min(cap, n_pkts)
        nev = min(ntr, len(data["eval"]["ts"]))
        tr = {k: v[:ntr] for k, v in data["train"].items()}
        ev = {k: v[:nev] for k, v in data["eval"].items()}
        c = min(chunk, ntr)
        # the FC training pass is identical for every MD backend: observe
        # once, snapshot, then fit + measure per MD backend from the
        # snapshot (fit() consumes the collected records and sets the
        # threshold, so both are restored per pair)
        svc = DetectionService(epoch=epoch, n_slots=n_slots, mode="exact",
                               backend=name, **kw)
        svc.observe_stream(tr, chunk=c)
        feats0 = list(svc._train_feats)
        state0 = jax.tree_util.tree_map(lambda x: x, svc.state)
        count0 = svc.pkt_count
        for md in md_backends:
            # re-validate against the service's md_kw on every switch, the
            # same invariant the DetectionService constructor establishes
            svc.md_backend = validate_md_options(md.strip(), svc.md_kw)
            svc._train_feats = list(feats0)
            svc.threshold = None
            svc.fit()
            svc.state = jax.tree_util.tree_map(lambda x: x, state0)
            svc.pkt_count = count0
            svc.process_stream(ev, chunk=c)     # warm-up/compile
            reps = 3 if name in ("scan", "pallas") else 1
            t = timeit(lambda: svc.process_stream(ev, chunk=c),
                       reps=reps, warmup=0)
            out[f"pipeline_{label}_x_{svc.md_backend}_pps"] = nev / t
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backends", default=DEFAULT_BACKENDS,
                    help=f"comma list from {available_backends()}; "
                         "sharded takes a :S shard-count suffix")
    ap.add_argument("--md-backends", default="einsum,pallas",
                    help=f"comma list from {available_md_backends()} "
                         "(used by --stage full)")
    ap.add_argument("--stage", choices=("fc", "full"), default="fc",
                    help="fc: per-backend FC component rates (default); "
                         "full: additionally measure the whole "
                         "FC -> record sampling -> MD pipeline per "
                         "(fc_backend x md_backend) pair")
    ap.add_argument("--chunk", type=int, default=2048,
                    help="streaming chunk size (packets per batch)")
    ap.add_argument("--service", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="also measure end-to-end DetectionService pps "
                         "(default: only with the full backend list)")
    args = ap.parse_args()
    n = 8000 if args.quick else 40000
    backends = tuple(b.strip() for b in args.backends.split(",") if b.strip())
    fc = fc_rates(n_pkts=n, backends=backends, chunk=args.chunk)
    md = md_rate()
    with_service = (args.service if args.service is not None
                    else args.backends == DEFAULT_BACKENDS)
    svc = (service_rate(n_pkts=min(n, 8000), chunk=args.chunk)
           if with_service else None)
    rates = (1, 64, 1024, 32768)
    # Fig8 pins the curve to the deployable batch pipeline (scan); other
    # backends are component diagnostics, not FC deployment rates
    curve_fc = fc.get("scan_pps", max(fc.values()))
    curve = {r: min(curve_fc, md * r) for r in rates}
    sharded = {k: v for k, v in fc.items() if k.startswith("sharded")}
    note = ("on-CPU single-core; Fig8 shape: throughput rises with "
            "sampling rate until FC-bound")
    if sharded and "scan_pps" in fc:
        best = max(sharded.values())
        if best <= fc["scan_pps"]:
            note += ("; sharded<=scan on this host: one device pays ~S x "
                     "serial work (every shard scans the full batch) on "
                     "the same packet-serial critical path — sharding "
                     "buys slot capacity/mesh placement, not single-host "
                     "pps (see module docstring)")
    out = {**fc, "md_records_per_s": md,
           "stable_pps_at_rate": curve,
           "note": note}
    if svc is not None:
        out["service_stream_pps"] = svc
    if args.stage == "full":
        mds = tuple(m.strip() for m in args.md_backends.split(",")
                    if m.strip())
        out.update(pipeline_rates(backends, md_backends=mds,
                                  n_pkts=min(n, 8000), chunk=args.chunk))
    for k, v in out.items():
        if isinstance(v, float):
            print(f"{k:32s} {v:12.0f}")
    print("stable pps:", {r: int(v) for r, v in curve.items()})
    save("throughput", out)


if __name__ == "__main__":
    main()
