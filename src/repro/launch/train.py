"""Training launcher.

Single-host execution path of the same code the 512-chip dry-run lowers:
  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --steps 50 \\
      --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ckpt

With --mesh data,model=AxB (and XLA_FLAGS host devices) it runs SPMD on a
host mesh; on real hardware the same flags drive the pod slice.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import TrainConfig, get_arch, reduced as reduce_cfg
from repro.configs.base import ShapeConfig
from repro.data import Prefetcher, lm_batches
from repro.distributed.mesh_rules import make_rules
from repro.distributed.params import batch_specs, opt_specs, param_specs
from repro.distributed.sharding import (AxisRules, named_shardings, set_mesh,
                                        use_rules)
from repro.models import build_model
from repro.training import CheckpointManager, init_train_state, make_train_step
from repro.training.fault import StragglerMonitor, resilient_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default=None, help="e.g. 2x4 (data x model)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg)
    tc = TrainConfig(learning_rate=args.lr, remat=args.remat,
                     microbatches=args.microbatches,
                     warmup_steps=max(args.steps // 10, 1))

    mesh = None
    rules_d = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        shp = ShapeConfig("cli", args.seq, args.batch, "train")
        rules_d = make_rules(cfg, shp, multi_pod=False, model_size=m,
                             dp_size=d)

    def run():
        state = init_train_state(model, tc, jax.random.PRNGKey(tc.seed))
        step_fn = make_train_step(model, tc)
        if mesh is not None:
            rules = AxisRules(rules_d)
            ps = param_specs(state["params"], cfg, rules,
                             mesh.devices.shape[1])
            os_ = opt_specs(state["opt"], ps, cfg, rules,
                            dict(zip(mesh.axis_names, mesh.devices.shape)),
                            tc.zero1)
            ss = {"params": ps, "opt": os_, "step": P()}
            bs = batch_specs(cfg, ShapeConfig("cli", args.seq, args.batch,
                                              "train"), rules)
            step_fn = jax.jit(
                step_fn,
                in_shardings=named_shardings(mesh, (ss, bs)),
                out_shardings=named_shardings(mesh, (ss, None)))
        else:
            step_fn = jax.jit(step_fn)

        batches = [
            {k: jnp.asarray(v) for k, v in b.items()}
            for b in Prefetcher(lm_batches(cfg.vocab, args.batch, args.seq,
                                           args.steps, seed=tc.seed))]
        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        mon = StragglerMonitor()
        t0 = time.time()
        out = resilient_loop(step_fn, state, batches, ckpt,
                             ckpt_every=args.ckpt_every, monitor=mon)
        dt = time.time() - t0
        toks = args.steps * args.batch * args.seq
        print(f"steps={out['completed']} restarts={out['restarts']} "
              f"stragglers={len(mon.stragglers)} "
              f"loss={float(out['metrics']['loss']):.4f} "
              f"tokens/s={toks / dt:.0f}")

    if mesh is not None:
        with use_rules(rules_d), set_mesh(mesh):
            run()
    else:
        run()


if __name__ == "__main__":
    main()
