"""Sharded flow-table backend: bit-exact equivalence with the serial oracle
across every attack generator and shard count, streaming chunk-carry, mesh
placement, and registry/service integration.

Slots never interact, so hash-partitioning the tables (shard = slot mod S)
and running the oracle's per-packet update inside each shard must reproduce
the serial backend *bit for bit* — these tests assert exact equality, far
inside the 1e-5 relative budget.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (N_FEATURES, available_backends, compute_features,
                        init_state, process_sharded, resolve_backend)
from repro.core.sharded import shard_tables, unshard_tables
from repro.traffic.generator import ATTACKS, benign_trace

N_PKTS = 256
N_SLOTS = 512

SHARD_COUNTS = (1, 4, 16)


def _trace(attack: str, seed: int = 0):
    """Benign background + one attack window, truncated to a fixed length
    so every parametrization shares one jit compilation per shard count."""
    rng = np.random.default_rng(seed)
    ben = benign_trace(160, 6.0, rng)
    atk = ATTACKS[attack](120, 1.0, 5.0, rng)
    out = {k: np.concatenate([ben[k], atk[k]]) for k in ben}
    order = np.argsort(out["ts"], kind="stable")
    out = {k: v[order][:N_PKTS] for k, v in out.items()}
    assert len(out["ts"]) == N_PKTS, attack
    return {k: jnp.asarray(v) for k, v in out.items() if k != "label"}


@pytest.fixture(scope="module")
def reference():
    cache = {}

    def get(attack):
        if attack not in cache:
            pk = _trace(attack)
            st, feats = compute_features(init_state(N_SLOTS), pk,
                                         backend="serial", mode="exact")
            cache[attack] = (pk, st, np.asarray(feats))
        return cache[attack]

    return get


@pytest.mark.parametrize("shards", SHARD_COUNTS)
@pytest.mark.parametrize("attack", sorted(ATTACKS))
def test_sharded_matches_serial_bitexact(reference, attack, shards):
    pk, st_ref, f_ref = reference(attack)
    st, f = compute_features(init_state(N_SLOTS), pk, backend="sharded",
                             shards=shards)
    f = np.asarray(f)
    assert f.shape == (N_PKTS, N_FEATURES)
    np.testing.assert_array_equal(f, f_ref, err_msg=f"{attack}/S={shards}")
    for grp in ("uni", "bi"):
        for k in st_ref[grp]:
            np.testing.assert_array_equal(
                np.asarray(st[grp][k]), np.asarray(st_ref[grp][k]),
                err_msg=f"{attack}/S={shards}/{grp}/{k}")


def test_sharded_switch_mode_matches_serial():
    """Round-robin counters are per-slot state, so switch mode shards too."""
    pk = _trace("syn_dos")
    _, f_ref = compute_features(init_state(N_SLOTS), pk, backend="serial",
                                mode="switch")
    _, f = compute_features(init_state(N_SLOTS), pk, backend="sharded",
                            mode="switch", shards=4)
    np.testing.assert_array_equal(np.asarray(f), np.asarray(f_ref))


def test_sharded_streaming_chunks_bitexact():
    """Chunked streaming with state carry == one-shot, bit for bit."""
    pk = _trace("mirai")
    _, f_once = compute_features(init_state(N_SLOTS), pk, backend="sharded",
                                 shards=4)
    st = init_state(N_SLOTS)
    outs = []
    for i in range(0, N_PKTS, 64):
        chunk = {k: v[i:i + 64] for k, v in pk.items()}
        st, f = compute_features(st, chunk, backend="sharded", shards=4)
        outs.append(np.asarray(f))
    np.testing.assert_array_equal(np.concatenate(outs), np.asarray(f_once))


def test_shard_unshard_roundtrip():
    st = init_state(64)
    for shards in (1, 4, 16):
        back = unshard_tables(shard_tables(st, shards), shards)
        for grp in ("uni", "bi"):
            for k in st[grp]:
                np.testing.assert_array_equal(np.asarray(back[grp][k]),
                                              np.asarray(st[grp][k]),
                                              err_msg=f"S={shards}/{grp}/{k}")


def test_sharded_rejects_uneven_partition():
    st = init_state(100)           # 100 % 16 != 0
    pk = _trace("syn_dos")
    with pytest.raises(ValueError, match="not divisible"):
        process_sharded(st, pk, shards=16)


def test_sharded_registered_with_both_modes():
    assert "sharded" in available_backends()
    assert resolve_backend("sharded") == "sharded"
    st = init_state(64)
    pk = _trace("syn_dos")
    # scan/pallas still reject switch mode; the error names the alternatives
    with pytest.raises(ValueError, match="sharded"):
        compute_features(st, pk, backend="scan", mode="switch")


def test_detection_service_sharded_backend():
    from repro.serving import DetectionService
    svc = DetectionService(epoch=64, n_slots=N_SLOTS, backend="sharded",
                           shards=4)
    idx = svc.observe_benign(_trace("mirai"))
    assert svc.pkt_count == N_PKTS
    assert list(idx) == [63, 127, 191, 255]          # global record indices
    assert svc._train_feats[0].shape == (4, N_FEATURES)


def test_sharded_under_mesh_rules():
    """flow_shards logical-axis placement: bound rules + a 1-device mesh
    must leave results bit-identical (the constraint is layout, not math)."""
    import jax
    from repro.distributed.sharding import set_mesh, use_rules

    pk = _trace("os_scan")
    _, f_ref = compute_features(init_state(N_SLOTS), pk, backend="serial",
                                mode="exact")
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    with set_mesh(mesh):
        with use_rules({"flow_shards": "data"}):
            _, f = compute_features(init_state(N_SLOTS), pk,
                                    backend="sharded", shards=4)
    np.testing.assert_array_equal(np.asarray(f), np.asarray(f_ref))
