from repro.traffic.generator import (  # noqa: F401
    ATTACKS, synth_trace, benign_trace, attack_trace, to_jnp,
)
