"""Hypothesis property tests on system invariants.

``hypothesis`` is an optional test dependency: when absent the whole module
degrades to a skip instead of aborting collection of the tier-1 suite.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import arith
from repro.core.parallel import seg_last_scan, seg_linear_scan
from repro.core.records import epoch_gather, epoch_indices
from repro.detection.metrics import auc

SETT = dict(max_examples=30, deadline=None)


# ---------------------------------------------------------------------------
# segmented linear scan == serial recurrence
# ---------------------------------------------------------------------------
@settings(**SETT)
@given(st.integers(2, 40), st.integers(1, 5), st.integers(0, 10 ** 6))
def test_seg_linear_scan_matches_serial(n, n_segs, seed):
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, n_segs, n))
    start = np.r_[True, seg[1:] != seg[:-1]]
    delta = rng.uniform(0.1, 1.0, n).astype(np.float32)
    x = rng.uniform(-2, 2, n).astype(np.float32)
    got = np.asarray(seg_linear_scan(jnp.asarray(start),
                                     jnp.asarray(delta), jnp.asarray(x)))
    want = np.zeros(n, np.float32)
    acc = 0.0
    for i in range(n):
        acc = x[i] if start[i] else delta[i] * acc + x[i]
        want[i] = acc
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-4)


@settings(**SETT)
@given(st.integers(2, 48), st.integers(1, 5), st.sampled_from([2, 3, 4, 8]),
       st.integers(0, 10 ** 6))
def test_seg_linear_scan_chunked_matches_flat(n, n_segs, chunks, seed):
    """The two-level bucketed form (local scans + tail-carry combine,
    core/bucketed.py) computes the same segmented recurrence as the flat
    scan for ANY cut positions — including cuts through the middle of a
    segment."""
    if n % chunks:
        n += chunks - n % chunks
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, n_segs, n))
    start = np.r_[True, seg[1:] != seg[:-1]]
    delta = rng.uniform(0.1, 1.0, n).astype(np.float32)
    x = rng.uniform(-2, 2, n).astype(np.float32)
    flat = np.asarray(seg_linear_scan(jnp.asarray(start),
                                      jnp.asarray(delta), jnp.asarray(x)))
    got = np.asarray(seg_linear_scan(jnp.asarray(start), jnp.asarray(delta),
                                     jnp.asarray(x), chunks=chunks))
    np.testing.assert_allclose(got, flat, rtol=2e-4, atol=1e-4)


@settings(**SETT)
@given(st.integers(2, 48), st.integers(1, 4), st.sampled_from([2, 3, 4, 8]),
       st.integers(0, 10 ** 6))
def test_seg_last_scan_chunked_matches_flat(n, n_segs, chunks, seed):
    """Latest-value carry across bucket cuts: found agrees everywhere and
    value is EXACTLY the flat scan's wherever found=True (selection, not
    arithmetic — no reassociation error).  Rows with found=False carry an
    unspecified value in BOTH forms (callers always select through found),
    so they are excluded."""
    if n % chunks:
        n += chunks - n % chunks
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, n_segs, n))
    start = np.r_[True, seg[1:] != seg[:-1]]
    valid = rng.random(n) < 0.5
    val = rng.uniform(-1, 1, n).astype(np.float32)
    f_flat, v_flat = seg_last_scan(jnp.asarray(start), jnp.asarray(valid),
                                   jnp.asarray(val))
    f_ch, v_ch = seg_last_scan(jnp.asarray(start), jnp.asarray(valid),
                               jnp.asarray(val), chunks=chunks)
    f_flat = np.asarray(f_flat)
    np.testing.assert_array_equal(np.asarray(f_ch), f_flat)
    np.testing.assert_array_equal(np.asarray(v_ch)[f_flat],
                                  np.asarray(v_flat)[f_flat])


@settings(**SETT)
@given(st.integers(2, 40), st.integers(1, 4), st.integers(0, 10 ** 6))
def test_seg_last_scan_matches_serial(n, n_segs, seed):
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, n_segs, n))
    start = np.r_[True, seg[1:] != seg[:-1]]
    valid = rng.random(n) < 0.5
    val = rng.uniform(-1, 1, n).astype(np.float32)
    found, got = seg_last_scan(jnp.asarray(start), jnp.asarray(valid),
                               jnp.asarray(val))
    found, got = np.asarray(found), np.asarray(got)
    last, has = 0.0, False
    for i in range(n):
        if start[i]:
            last, has = 0.0, False
        if valid[i]:
            last, has = val[i], True
        assert found[i] == has
        if has:
            assert abs(got[i] - last) < 1e-6


# ---------------------------------------------------------------------------
# O(S) cross-bucket combine: ragged sentinel tails + shard-crossing perms
# (tests/test_mesh.py carries seeded non-Hypothesis twins of these, so the
# invariants stay exercised on hosts without hypothesis installed)
# ---------------------------------------------------------------------------
@settings(**SETT)
@given(st.integers(2, 40), st.integers(1, 5), st.sampled_from([2, 4, 8]),
       st.integers(0, 10 ** 6))
def test_seg_scans_ragged_sentinel_tail_prefix_invariant(n, n_segs, chunks,
                                                         seed):
    """The bucketed pipeline pads ragged batches to a chunk multiple with
    sentinel rows that open their own dead segment at the tail
    (core/bucketed.py); the real-row PREFIX of both chunked scans must be
    exactly what the unpadded flat scan computes — padding may never leak
    backwards across the cut."""
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, n_segs, n))
    start = np.r_[True, seg[1:] != seg[:-1]]
    delta = rng.uniform(0.1, 1.0, n).astype(np.float32)
    x = rng.uniform(-2, 2, n).astype(np.float32)
    valid = rng.random(n) < 0.5
    pad = (-n) % chunks
    startp = np.r_[start, np.ones(pad, bool)]     # sentinels: own segments
    deltap = np.r_[delta, np.zeros(pad, np.float32)]
    xp = np.r_[x, np.zeros(pad, np.float32)]
    validp = np.r_[valid, np.zeros(pad, bool)]

    flat = np.asarray(seg_linear_scan(jnp.asarray(start), jnp.asarray(delta),
                                      jnp.asarray(x)))
    got = np.asarray(seg_linear_scan(jnp.asarray(startp), jnp.asarray(deltap),
                                     jnp.asarray(xp), chunks=chunks))[:n]
    np.testing.assert_allclose(got, flat, rtol=2e-4, atol=1e-4)

    f_flat, v_flat = seg_last_scan(jnp.asarray(start), jnp.asarray(valid),
                                   jnp.asarray(x))
    f_ch, v_ch = seg_last_scan(jnp.asarray(startp), jnp.asarray(validp),
                               jnp.asarray(xp), chunks=chunks)
    f_flat = np.asarray(f_flat)
    np.testing.assert_array_equal(np.asarray(f_ch)[:n], f_flat)
    np.testing.assert_array_equal(np.asarray(v_ch)[:n][f_flat],
                                  np.asarray(v_flat)[f_flat])


@settings(**SETT)
@given(st.integers(4, 64), st.integers(1, 4), st.sampled_from([2, 4]),
       st.integers(0, 10 ** 6))
def test_invert_perm_shard_crossing_scatter(n, n_keys, chunks, seed):
    """The bucketed backend sorts by flow key, scans chunked, and scatters
    back through ONE shared ``invert_perm`` — segments whose packets land
    in different chunks (shard-boundary crossers, near-certain with this
    few keys) must come back in original order carrying the same values as
    the flat sorted scan."""
    if n % chunks:
        n += chunks - n % chunks
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, n_keys, n)
    order = np.argsort(keys, kind="stable")
    inv = np.asarray(arith.invert_perm(jnp.asarray(order)))
    x = rng.uniform(-2, 2, n).astype(np.float32)
    np.testing.assert_array_equal(x[order][inv], x)   # exact round-trip
    sk = keys[order]
    startk = np.r_[True, sk[1:] != sk[:-1]]
    delta = rng.uniform(0.1, 1.0, n).astype(np.float32)
    args = (jnp.asarray(startk), jnp.asarray(delta[order]),
            jnp.asarray(x[order]))
    flat = np.asarray(seg_linear_scan(*args))[inv]
    ch = np.asarray(seg_linear_scan(*args, chunks=chunks))[inv]
    np.testing.assert_allclose(ch, flat, rtol=2e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# approximate arithmetic bounds
# ---------------------------------------------------------------------------
@settings(**SETT)
@given(st.floats(1.0, 1e6), st.floats(1.0, 1e6))
def test_shift_div_within_2x(a, b):
    """Rounding the divisor to the upper power of two under-estimates by at
    most 2x (plus the integer floor)."""
    got = float(arith.shift_div(jnp.float32(a), jnp.float32(b)))
    exact = a / b
    assert got <= exact + 1.0
    assert got >= exact / 2.0 - 1.0


@settings(**SETT)
@given(st.floats(1.0, 1e9))
def test_mathunit_sqrt_relative_error(x):
    got = float(arith.mathunit_sqrt(jnp.float32(x)))
    exact = float(np.sqrt(x))
    assert abs(got - exact) <= 0.12 * exact + 1.0


@settings(**SETT)
@given(st.floats(0.0, 50.0), st.floats(0.001, 10.0))
def test_decay_bounds(dt, lam):
    """Quantised decay brackets the exact decay from above within 2x."""
    ex = float(arith.exact_decay(lam, jnp.float32(dt)))
    qd = float(arith.quantized_decay(lam, jnp.float32(dt)))
    assert 0.0 <= ex <= 1.0 and 0.0 <= qd <= 1.0
    if lam * dt < 31:
        assert qd >= ex - 1e-6          # floor(k) halvings decay less
        assert qd <= ex * 2.0 + 1e-6


# ---------------------------------------------------------------------------
# sampling / metrics
# ---------------------------------------------------------------------------
@settings(**SETT)
@given(st.integers(1, 500), st.integers(1, 64), st.integers(0, 1000))
def test_epoch_indices_invariants(n, epoch, offset):
    idx = epoch_indices(n, epoch, offset)
    assert all(0 <= i < n for i in idx)
    assert all((i + offset + 1) % epoch == 0 for i in idx)
    # chunked == one-shot
    half = n // 2
    a = list(epoch_indices(half, epoch, offset))
    b = [i + half for i in epoch_indices(n - half, epoch, offset + half)]
    assert list(idx) == a + b


@settings(**SETT)
@given(st.integers(1, 400), st.integers(1, 64),
       st.one_of(st.integers(0, 10 ** 4),
                 st.integers(2 ** 31 - 100, 2 ** 31 + 100),
                 st.integers(2 ** 40, 2 ** 40 + 10 ** 4),
                 st.integers(2 ** 62, 2 ** 62 + 10 ** 4)))
def test_epoch_gather_exact_past_int31_offsets(n, epoch, offset):
    """The fused path's on-device ``epoch_gather`` takes only
    ``offset % epoch`` (an int32 residue), so it must reproduce the host
    ``epoch_indices`` EXACTLY for int64 stream positions far past 2**31
    packets — the terabit regime where a raw int32 offset would wrap."""
    want = epoch_indices(n, epoch, offset)
    idx, count = epoch_gather(n, epoch, jnp.int32(offset % epoch))
    idx, count = np.asarray(idx), int(count)
    assert count == len(want)
    np.testing.assert_array_equal(idx[:count], want)
    # padding past count is the documented zero fill
    assert not idx[count:].any()
    # global record positions reconstructed host-side stay exact in int64
    glob = idx[:count].astype(np.int64) + offset
    assert all((g + 1) % epoch == 0 for g in glob)


@settings(**SETT)
@given(st.integers(2, 100), st.integers(0, 10 ** 6))
def test_auc_separated_is_one(n, seed):
    rng = np.random.default_rng(seed)
    neg = rng.uniform(0, 0.4, n)
    pos = rng.uniform(0.6, 1.0, n)
    scores = np.r_[neg, pos]
    labels = np.r_[np.zeros(n), np.ones(n)]
    assert auc(scores, labels) == 1.0
    assert auc(-scores, labels) == 0.0


@settings(**SETT)
@given(st.integers(10, 200), st.integers(0, 10 ** 6))
def test_auc_random_is_half(n, seed):
    rng = np.random.default_rng(seed)
    scores = rng.uniform(0, 1, 2 * n)
    labels = np.r_[np.zeros(n), np.ones(n)]
    a = auc(scores, labels)
    assert 0.15 < a < 0.85


# ---------------------------------------------------------------------------
# flow-table hashing: salt independence, uniformity, sketch-row independence
# ---------------------------------------------------------------------------
def _rand_fields(n, n_fields, seed):
    rng = np.random.default_rng(seed)
    return tuple(rng.integers(0, 2 ** 32, n, dtype=np.uint32)
                 for _ in range(n_fields))


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(0, 10 ** 6),
       st.integers(0, 2 ** 32 - 1), st.integers(1, 2 ** 32 - 1))
def test_hash_salt_independence(n_fields, seed, salt, dsalt):
    """Two distinct salts behave as independent hash functions: over
    DISTINCT keys, the two 32-bit streams agree only at the ~2^-32 chance
    rate — operationally, a flow's slot under one salt tells you nothing
    about its slot under another (the property the collision fingerprint
    and the sketch rows rely on)."""
    from repro.core.state import np_hash_fields
    n = 2048
    fields = _rand_fields(n, n_fields, seed)
    a = np_hash_fields(fields, salt)
    b = np_hash_fields(fields, (salt ^ dsalt) & 0xFFFFFFFF)
    assert (a == b).mean() < 0.01
    # and slot-level (mod W) agreement stays near the 1/W chance rate
    w = 64
    assert ((a % w) == (b % w)).mean() < 4.0 / w


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6), st.sampled_from([16, 64, 256]))
def test_hash_slot_distribution_uniform(seed, w):
    """Random distinct keys spread evenly over W slots: every slot load
    stays within 5 sigma of the binomial expectation (a catastrophically
    biased mix — the failure mode that silently wrecks both the dense
    table and the sketch — lands far outside)."""
    from repro.core.state import KEY_SALTS, np_hash_fields
    n = 8192
    fields = _rand_fields(n, 2, seed)
    for salt in KEY_SALTS.values():
        counts = np.bincount(np_hash_fields(fields, salt) % w, minlength=w)
        exp = n / w
        tol = 5.0 * np.sqrt(exp * (1.0 - 1.0 / w))
        assert np.abs(counts - exp).max() <= tol, (salt, counts.max())


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(2, 4))
def test_sketch_rows_pairwise_independent(seed, rows):
    """Distinct sketch rows hash like independent functions: for any row
    pair the per-key column agreement stays near the 1/W chance rate, so
    a flow collided in one row is (almost) never collided in all of them
    — the premise of the Count-Min min-across-rows read."""
    from repro.core.sketch import sketch_packet_rows
    from repro.traffic.generator import to_jnp
    n, w = 4096, 64
    rng = np.random.default_rng(seed)
    pk = to_jnp({
        "ts": np.zeros(n, np.float32),
        "src": rng.integers(0, 2 ** 32, n, dtype=np.uint32),
        "dst": rng.integers(0, 2 ** 32, n, dtype=np.uint32),
        "sport": rng.integers(0, 2 ** 16, n, dtype=np.uint32),
        "dport": rng.integers(0, 2 ** 16, n, dtype=np.uint32),
        "proto": np.full(n, 6, np.uint32),
        "length": np.full(n, 100, np.float32),
    })
    cols = sketch_packet_rows(pk, rows, w)
    for key in ("src_ip", "channel", "socket"):
        c = np.asarray(cols[key])
        for i in range(rows):
            for j in range(i + 1, rows):
                agree = (c[:, i] == c[:, j]).mean()
                assert agree < 4.0 / w, (key, i, j, agree)


# ---------------------------------------------------------------------------
# Peregrine pipeline invariance: shifting all timestamps by a constant
# ---------------------------------------------------------------------------
@settings(max_examples=5, deadline=None)
@given(st.integers(0, 100))
def test_time_shift_invariance(seed):
    from repro.core import init_state, process_parallel
    rng = np.random.default_rng(seed)
    n = 60
    base = {
        "ts": np.sort(rng.uniform(0, 3, n)).astype(np.float32),
        "src": rng.integers(0, 4, n).astype(np.uint32),
        "dst": rng.integers(0, 4, n).astype(np.uint32),
        "sport": rng.integers(1000, 1004, n).astype(np.uint32),
        "dport": rng.integers(80, 82, n).astype(np.uint32),
        "proto": np.full(n, 6, np.uint32),
        "length": rng.integers(60, 1500, n).astype(np.float32),
    }
    st0 = init_state(128)
    _, f0 = process_parallel(st0, {k: jnp.asarray(v) for k, v in base.items()})
    shifted = dict(base, ts=base["ts"] + 50.0)
    _, f1 = process_parallel(st0, {k: jnp.asarray(v) for k, v in shifted.items()})
    np.testing.assert_allclose(np.asarray(f0), np.asarray(f1),
                               rtol=1e-3, atol=1.0)
