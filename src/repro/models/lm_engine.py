"""LM serving engine: prefill + decode over a fixed-slot batch
(continuous-batching-lite) — seed-era LM scaffolding, kept with the model
stack it serves.

``serve_step`` — the function the decode_* dry-run cells lower — is one new
token for every slot against the KV cache.  The engine wraps it with a
request queue: free slots are refilled by prefilling the incoming prompt and
splicing its KV into the batch cache at the slot index.

This module used to live at ``repro.serving.engine``; it moved here so the
``repro.serving`` package (the Peregrine detection plane) no longer drags
the LM model registry in at import time — ``serving/engine.py`` now hosts
the multi-tenant ``DetectionEngine`` (DESIGN.md §10), and an import-graph
test (tests/test_engine.py) pins ``repro.serving``'s allowed dependencies.
"""
from __future__ import annotations

import dataclasses
import queue
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig  # noqa: F401  (public API surface)
from repro.models.registry import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray          # (S,) int32
    max_new: int = 32


class ServeEngine:
    def __init__(self, model: Model, params, batch_slots: int, max_seq: int,
                 cache_dtype=jnp.bfloat16, greedy: bool = True):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_seq = max_seq
        self.cache = model.init_cache(batch_slots, max_seq, cache_dtype)
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.remaining = [0] * batch_slots
        self.outputs: Dict[int, List[int]] = {}
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self.tokens = jnp.zeros((batch_slots, 1), jnp.int32)
        self._decode = jax.jit(model.decode_step)

    def submit(self, req: Request) -> None:
        self.queue.put(req)

    def _admit(self) -> None:
        for slot in range(self.B):
            if self.active[slot] is None and not self.queue.empty():
                req = self.queue.get()
                # prefill the prompt for this slot alone, splice KV in
                logits, _, cache1 = self.model.forward(
                    self.params, {"tokens": req.prompt[None]},
                    build_cache=True, max_seq=self.max_seq)
                self.cache = _splice_cache(self.cache, cache1, slot)
                tok = int(jnp.argmax(logits[0, -1]))
                self.tokens = self.tokens.at[slot, 0].set(tok)
                self.active[slot] = req
                self.remaining[slot] = req.max_new - 1
                self.outputs[req.rid] = [tok]

    def step(self) -> int:
        """One engine tick: admit new requests, one decode step for all."""
        self._admit()
        if not any(self.active):
            return 0
        logits, self.cache = self._decode(self.params, self.tokens, self.cache)
        nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        self.tokens = nxt[:, None]
        live = 0
        for slot in range(self.B):
            req = self.active[slot]
            if req is None:
                continue
            self.outputs[req.rid].append(int(nxt[slot]))
            self.remaining[slot] -= 1
            if self.remaining[slot] <= 0:
                self.active[slot] = None
            else:
                live += 1
        return live

    def run(self, max_ticks: int = 1000) -> Dict[int, List[int]]:
        for _ in range(max_ticks):
            self._admit()
            if not any(self.active) and self.queue.empty():
                break
            self.step()
        return self.outputs


def _splice_cache(batch_cache, one_cache, slot: int):
    """Insert a single-request cache (batch 1) into slot ``slot``.

    Caveat: per-slot decode positions differ in a real continuous-batching
    server; this lite engine restarts all slots at the spliced request's
    ``pos`` only when the batch is empty, otherwise uses per-slot masking via
    the max pos (sufficient for the bundled examples/tests).
    """
    def leaf(b, o):
        if o is None:
            return b
        if b.ndim == 0:                 # pos scalar: furthest position wins
            return jnp.maximum(b, o.astype(b.dtype))
        if b.shape == o.shape:
            return o.astype(b.dtype)
        # leading layer axis, then batch axis
        if b.ndim >= 2 and o.shape[0] == b.shape[0] and o.shape[1] == 1:
            return jax.lax.dynamic_update_slice_in_dim(b, o.astype(b.dtype),
                                                       slot, axis=1)
        if o.shape[0] == 1:             # xlstm states: batch leading
            return jax.lax.dynamic_update_slice_in_dim(b, o.astype(b.dtype),
                                                       slot, axis=0)
        return b

    return jax.tree_util.tree_map(leaf, batch_cache, one_cache)
