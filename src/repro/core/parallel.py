"""TPU-native Peregrine feature computation: segmented associative scans.

The switch updates flow state one packet at a time.  On TPU we exploit that
the decayed-atom update  A_i = delta_i * A_{i-1} + x_i  is a *linear
first-order recurrence*, hence associative:

    (s2, a2) o (s1, a1) = (s1*s2, a1*s2 + a2)

so a whole packet batch is processed in O(log n) depth with
``jax.lax.associative_scan``, *segmented by flow* (sort by stream id, stable,
which preserves time order inside each stream).  Cross-direction state
(stale opposite-direction statistics, last-residual for SR) uses a segmented
"latest-value" scan, which is also associative.

Semantics are bit-for-bit the serial oracle's ``exact`` mode (tested to
float tolerance); the round-robin ``switch`` mode is inherently per-packet
serial and stays on the oracle path.

Requires ``pkts["ts"]`` sorted ascending (streams are time-ordered).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import arith
from repro.core.state import (
    LAMBDAS, N_BI, N_DECAY, N_UNI, packet_slots,
)

_LAM = jnp.asarray(LAMBDAS, jnp.float32)


# ---------------------------------------------------------------------------
# segmented-scan primitives
# ---------------------------------------------------------------------------
def seg_linear_scan(seg_start, delta, x):
    """Segmented A_i = delta_i * A_{i-1} + x_i (A resets at segment starts).

    seg_start: (n,) bool; delta, x: (n, ...) broadcastable. Returns A (n, ...).
    """
    f = seg_start
    while f.ndim < delta.ndim:
        f = f[..., None]
    f = jnp.broadcast_to(f, delta.shape)

    def combine(l, r):
        fl, sl, al = l
        fr, sr, ar = r
        return (fl | fr,
                jnp.where(fr, sr, sl * sr),
                jnp.where(fr, ar, al * sr + ar))

    _, _, a = jax.lax.associative_scan(combine, (f, delta, x), axis=0)
    return a


def seg_last_scan(seg_start, valid, value):
    """Segmented latest-valid-value (inclusive). Returns (found, last_value).

    ``found[i]`` False means no valid element yet in i's segment.
    """
    f = seg_start
    v = valid
    while f.ndim < value.ndim:
        f = f[..., None]
        v = v[..., None]
    f = jnp.broadcast_to(f, value.shape)
    v = jnp.broadcast_to(v, value.shape)

    def combine(l, r):
        fl, vl, xl = l
        fr, vr, xr = r
        found = jnp.where(fr, vr, vl | vr)
        val = jnp.where(fr, jnp.where(vr, xr, xr * 0), jnp.where(vr, xr, xl))
        return (fl | fr, found, val)

    _, found, val = jax.lax.associative_scan(combine, (f, v, value), axis=0)
    return found, val


def _segments(sorted_ids):
    n = sorted_ids.shape[0]
    start = jnp.concatenate([jnp.ones((1,), bool),
                             sorted_ids[1:] != sorted_ids[:-1]])
    end = jnp.concatenate([sorted_ids[1:] != sorted_ids[:-1],
                           jnp.ones((1,), bool)])
    return start, end


# ---------------------------------------------------------------------------
# one directional stream table pass
# ---------------------------------------------------------------------------
def stream_pass(tab, stream_ids, ts, lens, n_streams):
    """Vectorised decayed-atom update for one table of streams.

    tab: {"last_t","w","ls","ss"} each (n_streams, N_DECAY).
    stream_ids/ts/lens: (n,). Returns (per-packet atoms dict in ORIGINAL
    order, updated table).
    """
    n = stream_ids.shape[0]
    order = jnp.argsort(stream_ids, stable=True)
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(n))
    sid = stream_ids[order]
    t = ts[order]
    x = lens[order]
    start, end = _segments(sid)

    # per-packet decay: dt to previous packet in stream (table last_t at start)
    t_prev_in = jnp.concatenate([t[:1], t[:-1]])
    last_t_tab = tab["last_t"][sid]                       # (n, N_DECAY)
    fresh = last_t_tab < 0.0
    dt = jnp.where(start[:, None],
                   jnp.where(fresh, 0.0, t[:, None] - last_t_tab),
                   (t - t_prev_in)[:, None])
    dt = jnp.maximum(dt, 0.0)
    delta = jnp.exp2(-_LAM[None, :] * dt)
    delta = jnp.where(start[:, None] & fresh, 0.0, delta)

    def scan_atom(x_inc):
        """x_inc: (n, N_DECAY) per-packet increment."""
        return seg_linear_scan(start, delta, x_inc)

    # fold table carry into the first element: A_1 = delta_1*A_tab + x_1
    def with_carry(tab_a, x_inc):
        x0 = jnp.where(start[:, None], x_inc + delta * tab_a[sid], x_inc)
        return scan_atom(x0)

    ones = jnp.ones((n, N_DECAY))
    w = with_carry(tab["w"], ones)
    ls = with_carry(tab["ls"], jnp.broadcast_to(x[:, None], (n, N_DECAY)))
    ss = with_carry(tab["ss"], jnp.broadcast_to((x ** 2)[:, None], (n, N_DECAY)))

    # store back last element of each segment (indices unique by construction)
    sid_end = jnp.where(end, sid, n_streams)              # OOB drops
    new_tab = {
        "last_t": tab["last_t"].at[sid_end].set(
            jnp.broadcast_to(t[:, None], (n, N_DECAY)), mode="drop"),
        "w": tab["w"].at[sid_end].set(w, mode="drop"),
        "ls": tab["ls"].at[sid_end].set(ls, mode="drop"),
        "ss": tab["ss"].at[sid_end].set(ss, mode="drop"),
    }
    atoms = {"w": w[inv], "ls": ls[inv], "ss": ss[inv]}
    return atoms, new_tab


def _stats(w, ls, ss):
    mu = jnp.where(w > 0, ls / jnp.maximum(w, 1e-12), 0.0)
    ex2 = jnp.where(w > 0, ss / jnp.maximum(w, 1e-12), 0.0)
    var = jnp.abs(ex2 - mu ** 2)
    return mu, var, jnp.sqrt(var)


# ---------------------------------------------------------------------------
# channel pass: stale opposite stats + SR recurrence
# ---------------------------------------------------------------------------
def channel_pass(bi_k, slots, dirs, ts, lens, own_atoms, n_slots):
    """Cross-direction state for ONE bi key type.

    bi_k: the per-key-type slices of the bi table (each (n_slots, ...)).
    own_atoms: per-packet post-update atoms of the packet's own direction
    (original order, (n, N_DECAY) each).
    Returns (features pieces, updated bi_k).
    """
    n = slots.shape[0]
    order = jnp.argsort(slots, stable=True)
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(n))
    sid = slots[order]
    d = dirs[order]
    t = ts[order]
    start, end = _segments(sid)

    own_w = own_atoms["w"][order]
    own_ls = own_atoms["ls"][order]
    own_ss = own_atoms["ss"][order]

    # --- stale opposite-direction atoms: latest same-channel opposite pkt ---
    def latest_dir(X, tab_val):
        valid = d == X
        stacked = jnp.stack([own_w, own_ls, own_ss], axis=-1)  # (n,ND,3)
        found, val = seg_last_scan(start, valid, stacked)
        fallback = tab_val[sid]                                # (n,ND,3)
        return jnp.where(found, val, fallback)

    tabv = jnp.stack([bi_k["w"], bi_k["ls"], bi_k["ss"]], axis=-1)  # (ns,2,ND,3)
    v0 = latest_dir(0, tabv[:, 0])
    v1 = latest_dir(1, tabv[:, 1])
    opp = jnp.where((d == 0)[:, None, None], v1, v0)          # (n,ND,3)
    opp_w, opp_ls, opp_ss = opp[..., 0], opp[..., 1], opp[..., 2]

    # --- residuals ---
    mu_own, var_own, sig_own = _stats(own_w, own_ls, own_ss)
    lens_s = lens[order]
    r = lens_s[:, None] - mu_own                              # (n, ND)

    def latest_res(X, tab_res):
        valid = d == X
        found, val = seg_last_scan(start, valid, r)
        return jnp.where(found, val, tab_res[sid])

    r0 = latest_res(0, bi_k["res_last"][:, 0])
    r1 = latest_res(1, bi_k["res_last"][:, 1])
    r_opp = jnp.where((d == 0)[:, None], r1, r0)

    # --- SR recurrence over the whole channel (both directions) ---
    t_prev = jnp.concatenate([t[:1], t[:-1]])
    sr_lt_tab = bi_k["sr_last_t"][sid]                        # (n, ND)
    fresh = sr_lt_tab < 0.0
    dt = jnp.where(start[:, None],
                   jnp.where(fresh, 0.0, t[:, None] - sr_lt_tab),
                   (t - t_prev)[:, None])
    dsr = jnp.exp2(-_LAM[None, :] * jnp.maximum(dt, 0.0))
    dsr = jnp.where(start[:, None] & fresh, 0.0, dsr)
    x_sr = r * r_opp
    x_sr = jnp.where(start[:, None], x_sr + dsr * bi_k["sr"][sid], x_sr)
    sr = seg_linear_scan(start, dsr, x_sr)

    # --- bidirectional stats ---
    mu_opp, var_opp, sig_opp = _stats(opp_w, opp_ls, opp_ss)
    mag = jnp.sqrt(mu_own ** 2 + mu_opp ** 2)
    rad = jnp.sqrt(var_own ** 2 + var_opp ** 2)
    wsum = own_w + opp_w
    cov = jnp.where(wsum > 0, sr / jnp.maximum(wsum, 1e-12), 0.0)
    sden = sig_own * sig_opp
    pcc = jnp.where(sden > 0, cov / jnp.maximum(sden, 1e-12), 0.0)

    feats = jnp.stack([own_w, mu_own, sig_own, mag, rad, cov, pcc],
                      axis=-1)                                 # (n, ND, 7)
    feats = feats[inv]

    # --- store-back (segment ends; res_last per direction: last of each) ---
    sid_end = jnp.where(end, sid, n_slots)
    new_bi = dict(bi_k)
    new_bi["sr"] = bi_k["sr"].at[sid_end].set(sr, mode="drop")
    new_bi["sr_last_t"] = bi_k["sr_last_t"].at[sid_end].set(
        jnp.broadcast_to(t[:, None], sr.shape), mode="drop")
    # last residual of each (channel, direction): last occurrence of the
    # composite key sid*2+d (unique per (segment, dir) since segments are
    # channel-contiguous) — resort by that key, take segment ends.
    key2 = sid * 2 + d
    o2 = jnp.argsort(key2, stable=True)
    k2s = key2[o2]
    _, end2 = _segments(k2s)
    sid2_end = jnp.where(end2, k2s // 2, n_slots)
    d2 = k2s % 2
    new_bi["res_last"] = new_bi["res_last"].at[sid2_end, d2].set(
        r[o2], mode="drop")
    return feats, new_bi


@jax.jit
def process_parallel(state: Dict, pkts: Dict[str, jax.Array]
                     ) -> Tuple[Dict, jax.Array]:
    """Exact-mode Peregrine FC via segmented scans. Same I/O as
    ``process_serial(..., mode="exact")``."""
    from repro.core.state import state_slots
    n_slots = state_slots(state)
    sl = packet_slots(pkts, n_slots)
    ts = pkts["ts"].astype(jnp.float32)
    lens = pkts["length"].astype(jnp.float32)
    feats = []

    # ---- unidirectional ----
    new_uni = {k: state["uni"][k] for k in state["uni"]}
    for ki, key in enumerate(("src_mac_ip", "src_ip")):
        tab = {f: state["uni"][f][ki] for f in ("last_t", "w", "ls", "ss")}
        atoms, new_tab = stream_pass(tab, sl[key], ts, lens, n_slots)
        mu, var, sig = _stats(atoms["w"], atoms["ls"], atoms["ss"])
        feats.append(jnp.stack([atoms["w"], mu, sig], axis=-1))  # (n,ND,3)
        for f in new_tab:
            new_uni[f] = new_uni[f].at[ki].set(new_tab[f])

    # ---- bidirectional ----
    new_bi = {k: state["bi"][k] for k in state["bi"]}
    bi_feats = []
    for ki, key in enumerate(("channel", "socket")):
        # directional streams: stream id = slot*2 + dir
        stream_ids = sl[key] * 2 + sl["dir"]
        tab = {f: state["bi"][f][ki].reshape(2 * n_slots, N_DECAY)
               for f in ("last_t", "w", "ls", "ss")}
        # note: table layout (n_slots, 2, ND) -> stream id slot*2+dir matches
        atoms, new_tab = stream_pass(tab, stream_ids, ts, lens, 2 * n_slots)
        bi_k = {f: state["bi"][f][ki] for f in
                ("sr", "sr_last_t", "res_last")}
        bi_k["w"] = new_tab["w"].reshape(n_slots, 2, N_DECAY)
        bi_k["ls"] = new_tab["ls"].reshape(n_slots, 2, N_DECAY)
        bi_k["ss"] = new_tab["ss"].reshape(n_slots, 2, N_DECAY)
        # stale-opposite fallback must be the PRE-batch table values:
        bi_k_pre = dict(bi_k)
        for f in ("w", "ls", "ss"):
            bi_k_pre[f] = state["bi"][f][ki]
        fts, upd = channel_pass(bi_k_pre, sl[key], sl["dir"], ts, lens,
                                atoms, n_slots)
        bi_feats.append(fts)
        for f in ("last_t", "w", "ls", "ss"):
            new_bi[f] = new_bi[f].at[ki].set(
                new_tab[f].reshape(n_slots, 2, N_DECAY))
        for f in ("sr", "sr_last_t", "res_last"):
            new_bi[f] = new_bi[f].at[ki].set(upd[f])

    n = ts.shape[0]
    out = jnp.concatenate(
        [f.reshape(n, -1) for f in feats] +
        [f.reshape(n, -1) for f in bi_feats], axis=-1)
    new_state = {"uni": new_uni, "bi": new_bi}
    return new_state, out
