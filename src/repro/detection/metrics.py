"""Detection metrics: AUC (rank statistic) and F1 at an FPR-derived threshold
(paper Appendix B)."""
from __future__ import annotations

import numpy as np


def auc(scores: np.ndarray, labels: np.ndarray) -> float:
    """Area under ROC via the Mann-Whitney U statistic (ties handled)."""
    scores = np.asarray(scores, np.float64)
    labels = np.asarray(labels).astype(bool)
    n_pos = int(labels.sum())
    n_neg = int((~labels).sum())
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(scores)
    ranks[order] = np.arange(1, len(scores) + 1)
    # average ranks for ties
    s_sorted = scores[order]
    i = 0
    while i < len(s_sorted):
        j = i
        while j + 1 < len(s_sorted) and s_sorted[j + 1] == s_sorted[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    u = ranks[labels].sum() - n_pos * (n_pos + 1) / 2.0
    return float(u / (n_pos * n_neg))


def threshold_at_fpr(scores_benign: np.ndarray, fpr: float) -> float:
    """Score threshold with the given false-positive rate on benign scores."""
    return float(np.quantile(np.asarray(scores_benign, np.float64), 1.0 - fpr))


def f1_at_fpr(scores: np.ndarray, labels: np.ndarray, fpr: float) -> float:
    labels = np.asarray(labels).astype(bool)
    if labels.all() or (~labels).any() is False:
        return float("nan")
    thr = threshold_at_fpr(scores[~labels], fpr)
    pred = scores > thr
    tp = int((pred & labels).sum())
    fp = int((pred & ~labels).sum())
    fn = int((~pred & labels).sum())
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    if prec + rec == 0:
        return 0.0
    return float(2 * prec * rec / (prec + rec))
