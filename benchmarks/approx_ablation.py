"""§5.4 ablation (attacks 5-12 discussion): does the switch's approximate
arithmetic hurt detection?  The paper conjectures it can even act as a
regularizer.  We run identical traces through exact vs switch FC and compare
AUC per attack.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import save
from repro.detection.sweep import sweep_attack
from repro.traffic import ATTACKS, synth_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    attacks = (("syn_dos", "ssdp_flood") if args.quick
               else tuple(ATTACKS))
    n = 6000 if args.quick else 30000
    rate = 64
    out = {}
    better = 0
    for a in attacks:
        data = synth_trace(a, n_train=n, n_benign_eval=n // 2,
                           n_attack=n // 2, seed=11)
        ex = sweep_attack(data, [rate], mode="exact")["peregrine"][rate]["auc"]
        sw = sweep_attack(data, [rate], mode="switch")["peregrine"][rate]["auc"]
        out[a] = {"exact": ex, "switch": sw, "delta": sw - ex}
        better += sw >= ex
        print(f"{a:18s} exact={ex:.3f} switch={sw:.3f} delta={sw - ex:+.3f}")
    print(f"switch >= exact on {better}/{len(attacks)} attacks "
          f"(paper: approximations sometimes improve AUC)")
    save("approx_ablation", {"rate": rate, "per_attack": out,
                             "switch_geq_exact": better,
                             "n_attacks": len(attacks)})


if __name__ == "__main__":
    main()
