from repro.distributed.sharding import (  # noqa: F401
    AxisRules, use_rules, current_rules, lshard, logical_spec,
)
