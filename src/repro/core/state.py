"""Flow-state tables — the TPU analogue of the switch's register arrays.

Slots are direct-indexed by ``hash(flow_key) % n_slots`` with *no* collision
resolution, exactly like the switch's stateful SRAM arrays (colliding flows
merge — part of the fidelity model, noted in DESIGN.md §1).

Four decay instances per atom (lambda = 10, 1, 1/10, 1/60 — windows 100ms /
1s / 10s / 60s) as in §4.

State layout is PLUGGABLE (DESIGN.md §11): ``init_state(n,
state_backend=...)`` selects a registered :class:`StateBackend` — ``dense``
(the direct-indexed slot arrays below, the default) or ``sketch``
(Count-Min multi-row hashed tables with conservative update,
``core/sketch.py``).  Everything downstream — ``compute_features``, the
fused serving step, :class:`StatePool` — identifies the layout structurally
(``state_backend_of``) and routes accordingly, so a state dict remains the
only handle that ever crosses an API boundary.

Multi-tenant serving stores N independent flow tables as ONE stacked pytree
with a leading tenant axis (:class:`StatePool`, DESIGN.md §10): N tenants
cost one device allocation per leaf, tenant slots are allocated/freed/reset
by index, and the tenant-batched fused step (serving/fused.py) gathers and
scatters slots inside one donated jit so tenant states never mix.
"""
from __future__ import annotations

from typing import Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

LAMBDAS = (10.0, 1.0, 0.1, 1.0 / 60.0)
N_DECAY = len(LAMBDAS)

# key types
UNI_KEYS = ("src_mac_ip", "src_ip")            # unidirectional stats
BI_KEYS = ("channel", "socket")                # bidirectional stats
N_UNI, N_BI = len(UNI_KEYS), len(BI_KEYS)

UNI_STATS = ("w", "mean", "std")
BI_STATS = ("w", "mean", "std", "magnitude", "radius", "cov", "pcc")
N_FEATURES = N_UNI * N_DECAY * len(UNI_STATS) + N_BI * N_DECAY * len(BI_STATS)

FEATURE_NAMES = tuple(
    f"{k}:{lam}:{s}"
    for k in UNI_KEYS for lam in LAMBDAS for s in UNI_STATS
) + tuple(
    f"{k}:{lam}:{s}"
    for k in BI_KEYS for lam in LAMBDAS for s in BI_STATS
)


# ---------------------------------------------------------------------------
# State-backend registry
# ---------------------------------------------------------------------------
class StateBackend(NamedTuple):
    """One pluggable flow-state layout.

    The implicit dense-state contract — init / static slot count /
    structural identification / per-batch compute — made explicit, so a
    second layout (``sketch``) can ride every downstream subsystem
    (backend dispatch, fused serving, :class:`StatePool`) without those
    subsystems growing per-layout branches.
    """
    name: str
    #: (n_slots, **cfg) -> fresh state pytree
    init: Callable[..., Dict]
    #: state -> static slot/width count (jit-safe: reads shapes only)
    slots: Callable[[Dict], int]
    #: state -> does this pytree belong to this backend?  Structural only
    #: (key presence), so it works on tracers and stacked pool pytrees.
    matches: Callable[[Dict], bool]
    #: state -> reconstruction kwargs (everything ``init`` needs besides
    #: ``n_slots``) — how StatePool/engine rebuild fresh states of the
    #: same shape.  Host-side only (may concretise scalar leaves).
    config: Callable[[Dict], Dict]
    #: optional (state, pkts, mode=..., fc_backend=..., **kw) ->
    #: (state, feats): backends whose update does NOT ride the dense FC
    #: registry (sketch).  None = dense contract, FC registry dispatches.
    compute: Optional[Callable] = None


_STATE_BACKENDS: Dict[str, StateBackend] = {}

# backends that register themselves on first import
_LAZY_STATE_MODULES = {"sketch": "repro.core.sketch"}


def register_state_backend(backend: StateBackend) -> StateBackend:
    _STATE_BACKENDS[backend.name] = backend
    return backend


def available_state_backends() -> Tuple[str, ...]:
    return tuple(sorted(set(_STATE_BACKENDS) | set(_LAZY_STATE_MODULES)))


def resolve_state_backend(name: str) -> StateBackend:
    """The registered :class:`StateBackend` for ``name`` (lazily importing
    modules that register on import); raises on unknown names."""
    if name not in _STATE_BACKENDS and name in _LAZY_STATE_MODULES:
        import importlib
        importlib.import_module(_LAZY_STATE_MODULES[name])
    if name not in _STATE_BACKENDS:
        raise ValueError(f"unknown state backend {name!r}; "
                         f"available: {available_state_backends()}")
    return _STATE_BACKENDS[name]


def state_spec_of(state: Dict) -> StateBackend:
    """The :class:`StateBackend` a state pytree belongs to, identified
    structurally — works on concrete states, tracers, and stacked pools."""
    for spec in _STATE_BACKENDS.values():
        if spec.matches(state):
            return spec
    for name in _LAZY_STATE_MODULES:
        spec = resolve_state_backend(name)
        if spec.matches(state):
            return spec
    raise ValueError("state pytree matches no registered state backend "
                     f"(available: {available_state_backends()})")


def state_backend_of(state: Dict) -> str:
    return state_spec_of(state).name


def state_config(state: Dict) -> Dict:
    """Reconstruction kwargs for ``init_state`` (minus ``n_slots``): pass
    to build fresh states with the same layout/parameters.  Host-side."""
    return dict(state_spec_of(state).config(state))


def init_state(n_slots: int, state_backend: str = "dense", **state_kw) -> Dict:
    """Fresh flow tables for the selected state backend.

    ``dense`` (default): direct-indexed slot arrays — uni tables
    (N_UNI, n_slots, N_DECAY) atoms; bi tables carry a direction axis
    (N_BI, n_slots, 2, N_DECAY) plus channel-level SR state.

    ``sketch``: Count-Min multi-row hashed tables (core/sketch.py) of
    width ``n_slots`` — pass ``rows=R`` / ``evict_age=seconds``.
    """
    return resolve_state_backend(state_backend).init(n_slots, **state_kw)


def _dense_init(n_slots: int) -> Dict:
    z = jnp.zeros
    return {
        "uni": {
            "last_t": z((N_UNI, n_slots, N_DECAY)) - 1.0,
            "w": z((N_UNI, n_slots, N_DECAY)),
            "ls": z((N_UNI, n_slots, N_DECAY)),
            "ss": z((N_UNI, n_slots, N_DECAY)),
            "rr": z((N_UNI, n_slots), jnp.int32),
        },
        "bi": {
            "last_t": z((N_BI, n_slots, 2, N_DECAY)) - 1.0,
            "w": z((N_BI, n_slots, 2, N_DECAY)),
            "ls": z((N_BI, n_slots, 2, N_DECAY)),
            "ss": z((N_BI, n_slots, 2, N_DECAY)),
            "sr": z((N_BI, n_slots, N_DECAY)),
            "sr_last_t": z((N_BI, n_slots, N_DECAY)) - 1.0,
            "res_last": z((N_BI, n_slots, 2, N_DECAY)),
            "rr": z((N_BI, n_slots), jnp.int32),
        },
    }


register_state_backend(StateBackend(
    name="dense",
    init=_dense_init,
    slots=lambda s: s["uni"]["w"].shape[1],
    # rr counters exist only in the dense layout (round-robin switch mode)
    matches=lambda s: isinstance(s, dict) and "rr" in s.get("uni", {}),
    config=lambda s: {},
    compute=None,
))


def state_slots(state: Dict) -> int:
    """Static slot count (dense) / table width (sketch), derived from
    table shapes via the state's backend (jit-safe)."""
    return state_spec_of(state).slots(state)


def init_state_stacked(n_tenants: int, n_slots: int,
                       state_backend: str = "dense", **state_kw) -> Dict:
    """N fresh flow-table states as ONE stacked pytree (leading tenant
    axis on every leaf) — the single-allocation layout :class:`StatePool`
    manages and the tenant-batched fused step vmaps over."""
    one = init_state(n_slots, state_backend=state_backend, **state_kw)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_tenants,) + x.shape)
        # broadcast_to aliases one buffer across tenants; materialise so
        # per-tenant scatter updates (pool.at[tid].set) stay independent
        .copy(), one)


class StatePool:
    """Bounded pool of per-tenant flow-table states, stacked on device.

    The pool owns ``n_tenants`` tenant slots stored as one stacked pytree
    (``init_state_stacked``): each leaf carries a leading tenant axis, so
    the whole pool is a single device allocation per table, not N — and
    the tenant-batched fused serving step (serving/fused.py) can gather
    any subset of tenant states, run them through one donated jit, and
    scatter them back without the states ever mixing.

    Lifecycle: ``alloc()`` claims a free slot (its state is freshly
    reset), ``free(tid)`` releases it, ``reset(tid)`` zeroes a live
    tenant's tables in place (a new capture on the same slot).  The
    stacked pytree handle lives at ``pool.stacked``; callers that pass it
    through a donated step must write the returned handle back (the
    engine does — DESIGN.md §8 donation contract applies unchanged).
    """

    def __init__(self, n_tenants: int, n_slots: int,
                 state_backend: str = "dense", **state_kw):
        if n_tenants < 1:
            raise ValueError(f"need at least one tenant slot, got {n_tenants}")
        self.n_tenants = int(n_tenants)
        self.n_slots = int(n_slots)
        self.state_backend = resolve_state_backend(state_backend).name
        self.state_kw = dict(state_kw)
        self.stacked = init_state_stacked(n_tenants, n_slots,
                                          state_backend=self.state_backend,
                                          **self.state_kw)
        self._live: List[bool] = [False] * n_tenants
        # one fresh single-tenant state kept as the reset template so
        # reset() never rebuilds it (host->device) per call
        self._fresh = init_state(n_slots, state_backend=self.state_backend,
                                 **self.state_kw)
        # pristine[t] <=> slot t is known to hold a fresh state, letting
        # alloc() skip the full-pool copy a reset costs; anything that
        # writes a slot outside reset() must clear the flag (write() and
        # the engine's dispatch scatter do — mark_dirty)
        self._pristine: List[bool] = [True] * n_tenants

    # ---- slot lifecycle ----
    @property
    def live(self) -> Tuple[int, ...]:
        """Currently allocated tenant ids, ascending."""
        return tuple(t for t, on in enumerate(self._live) if on)

    @property
    def free_slots(self) -> int:
        return self.n_tenants - len(self.live)

    def alloc(self) -> int:
        """Claim the lowest free tenant slot (freshly reset); raises
        ``RuntimeError`` when the pool is exhausted — the caller decides
        whether that means shed, queue, or grow a new pool."""
        for t, on in enumerate(self._live):
            if not on:
                self._live[t] = True
                if not self._pristine[t]:
                    self.reset(t)
                return t
        raise RuntimeError(
            f"StatePool exhausted: all {self.n_tenants} tenant slots live")

    def free(self, tid: int) -> None:
        """Release a tenant slot.  The actual table reset is deferred to
        the next ``alloc`` of the slot (pristine tracking), so detach is
        O(1) — a later alloc still always starts clean."""
        self._check(tid)
        self._live[tid] = False

    def reset(self, tid: int) -> None:
        """Zero tenant ``tid``'s flow tables in place (fresh capture)."""
        if not 0 <= tid < self.n_tenants:
            raise IndexError(f"tenant {tid} out of range 0..{self.n_tenants - 1}")
        self.stacked = jax.tree_util.tree_map(
            lambda p, f: p.at[tid].set(f), self.stacked, self._fresh)
        self._pristine[tid] = True

    def mark_dirty(self, tids) -> None:
        """Record that ``tids``' slots no longer hold fresh state.  Callers
        that scatter into ``pool.stacked`` directly (the engine's donated
        dispatch does) must call this so a freed slot's next alloc knows to
        reset it."""
        for t in tids:
            self._pristine[int(t)] = False

    def _check(self, tid: int) -> None:
        if not 0 <= tid < self.n_tenants:
            raise IndexError(f"tenant {tid} out of range 0..{self.n_tenants - 1}")
        if not self._live[tid]:
            raise KeyError(f"tenant {tid} is not allocated")

    # ---- state access ----
    def read(self, tid: int) -> Dict:
        """A standalone COPY of tenant ``tid``'s state (safe to keep
        across later pool updates/donations)."""
        self._check(tid)
        return jax.tree_util.tree_map(lambda x: jnp.copy(x[tid]), self.stacked)

    def write(self, tid: int, state: Dict) -> None:
        """Install a standalone single-tenant state into slot ``tid``."""
        self._check(tid)
        self.stacked = jax.tree_util.tree_map(
            lambda p, s: p.at[tid].set(s), self.stacked, state)
        self._pristine[tid] = False


# ---------------------------------------------------------------------------
# Flow-key hashing (CRC-like mix, vectorised)
# ---------------------------------------------------------------------------
def _mix(h: jax.Array, v: jax.Array) -> jax.Array:
    h = (h ^ v) * jnp.uint32(0x9E3779B1)
    return h ^ (h >> 15)


def hash_fields(fields, salt: int) -> jax.Array:
    h = jnp.full(fields[0].shape, jnp.uint32(salt ^ 0x811C9DC5))
    for f in fields:
        h = _mix(h, f.astype(jnp.uint32))
    return h


# per-key-type base hash salts; the sketch backend derives its row salts
# from these (row 0 == the dense salt, so a 1-row sketch of equal width
# maps flows to exactly the dense slots — the degeneracy tests rely on it)
KEY_SALTS = {"src_mac_ip": 1, "src_ip": 2, "channel": 3, "socket": 4}


def key_fields(pkts) -> Tuple[Dict[str, Tuple], jax.Array]:
    """Canonicalised per-key-type hash-field tuples + channel dir bit.

    The single definition of WHAT gets hashed per key type; every slot
    mapping (dense ``packet_slots``, the sketch rows, the collision
    fingerprints) derives from it, so key canonicalisation can never
    drift between state backends.
    """
    src, dst = pkts["src"], pkts["dst"]
    sport, dport = pkts["sport"], pkts["dport"]
    lo_is_src = (src < dst) | ((src == dst) & (sport <= dport))
    ip_lo = jnp.where(lo_is_src, src, dst)
    ip_hi = jnp.where(lo_is_src, dst, src)
    p_lo = jnp.where(lo_is_src, sport, dport)
    p_hi = jnp.where(lo_is_src, dport, sport)
    fields = {
        "src_mac_ip": (src,),
        "src_ip": (src,),
        "channel": (ip_lo, ip_hi),
        "socket": (ip_lo, ip_hi, p_lo, p_hi, pkts["proto"]),
    }
    return fields, (~lo_is_src).astype(jnp.int32)


def packet_slots(pkts: Dict[str, jax.Array], n_slots: int) -> Dict[str, jax.Array]:
    """Per-packet slot indices + channel direction bit.

    pkts: {ts, src, dst, sport, dport, proto, length} arrays of shape (n,).
    Channel/socket keys are canonicalised (min/max endpoint) so both
    directions land in the same slot; ``dir`` = 0 if src is the canonical
    low endpoint else 1.  Equal IPs (same-host/loopback socket pairs) break
    the tie on ports, so the two directions of a swapped-port socket still
    share a slot with opposite ``dir`` bits instead of merging.
    """
    fields, dirb = key_fields(pkts)
    ns = jnp.uint32(n_slots)
    out = {k: (hash_fields(f, KEY_SALTS[k]) % ns).astype(jnp.int32)
           for k, f in fields.items()}
    out["dir"] = dirb
    return out


# ---------------------------------------------------------------------------
# Dense-path slot-collision telemetry (host-side numpy twin of the hash)
# ---------------------------------------------------------------------------
# salt for the collision fingerprint: independent of every table salt
# (KEY_SALTS and the sketch row salts), so two flows sharing a slot almost
# never share a fingerprint
_FP_SALT = 0x7F4A7C15


def np_hash_fields(fields, salt: int) -> np.ndarray:
    """Numpy twin of :func:`hash_fields` — bit-identical on uint32 inputs
    (property-tested), so per-chunk telemetry never touches the device."""
    h = np.full(np.shape(fields[0]), np.uint32(salt ^ 0x811C9DC5), np.uint32)
    for f in fields:
        h = (h ^ np.asarray(f, np.uint32)) * np.uint32(0x9E3779B1)
        h = h ^ (h >> np.uint32(15))
    return h


def _np_key_fields(pkts) -> Dict[str, Tuple]:
    src = np.asarray(pkts["src"])
    dst = np.asarray(pkts["dst"])
    sport = np.asarray(pkts["sport"])
    dport = np.asarray(pkts["dport"])
    lo_is_src = (src < dst) | ((src == dst) & (sport <= dport))
    ip_lo = np.where(lo_is_src, src, dst)
    ip_hi = np.where(lo_is_src, dst, src)
    p_lo = np.where(lo_is_src, sport, dport)
    p_hi = np.where(lo_is_src, dport, sport)
    return {
        "src_mac_ip": (src,),
        "src_ip": (src,),
        "channel": (ip_lo, ip_hi),
        "socket": (ip_lo, ip_hi, p_lo, p_hi, np.asarray(pkts["proto"])),
    }


def slot_collisions(pkts: Dict[str, np.ndarray],
                    n_slots: int) -> Dict[str, int]:
    """Distinct flow keys aliased onto an occupied slot in this chunk.

    Per key type: hash every packet to its dense slot, fingerprint the
    flow key with an independent salt, and count ``distinct (slot, key)
    pairs − distinct slots`` — i.e. how many distinct flows merged into a
    slot some other flow already claims.  0 everywhere ⇔ the chunk was
    collision-free.  Pure numpy (no device round-trip): cheap enough to
    run per dispatched chunk in ``DetectionEngine`` telemetry.
    """
    out = {}
    total = 0
    for name, f in _np_key_fields(pkts).items():
        slot = np_hash_fields(f, KEY_SALTS[name]) % np.uint32(n_slots)
        fp = np_hash_fields(f, _FP_SALT)
        pair = slot.astype(np.uint64) << np.uint64(32) | fp.astype(np.uint64)
        c = int(np.unique(pair).size - np.unique(slot).size)
        out[name] = c
        total += c
    out["total"] = total
    return out
