"""Peregrine control-plane service: the middlebox-server side of the paper.

Consumes packet batches (what the switch would forward), runs the data-plane
feature pipeline, emits per-epoch feature records, and scores them with
KitNET — the full §3.2 workflow as one object.  Tracks the running packet
count so epochs are continuous across batches, and keeps flow-table state
warm between calls (exactly the switch's persistent registers).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import (compute_features, default_backend, init_state,
                        resolve_backend)
from repro.core.records import epoch_indices
from repro.detection.kitnet import KitNet, score_kitnet, train_kitnet
from repro.traffic.generator import to_jnp


class DetectionService:
    def __init__(self, epoch: int = 1024, n_slots: int = 8192,
                 mode: str = "exact", threshold: Optional[float] = None,
                 backend: Optional[str] = None):
        self.epoch = epoch
        self.mode = mode
        self.backend = resolve_backend(backend if backend is not None
                                       else default_backend(mode))
        self.state = init_state(n_slots)
        self.net: Optional[KitNet] = None
        self.threshold = threshold
        self.pkt_count = 0
        self._train_feats = []

    # ---- data-plane step (would run on the switch) ----
    def _fc(self, pkts: Dict[str, np.ndarray]) -> np.ndarray:
        pk = to_jnp(pkts)
        self.state, feats = compute_features(self.state, pk,
                                             backend=self.backend,
                                             mode=self.mode)
        return np.asarray(feats)

    # ---- training phase ----
    def observe_benign(self, pkts: Dict[str, np.ndarray]) -> None:
        feats = self._fc(pkts)
        idx = epoch_indices(len(feats), self.epoch, self.pkt_count)
        self.pkt_count += len(feats)
        if len(idx):
            self._train_feats.append(feats[idx])

    def fit(self, seed: int = 0, fpr: float = 0.01) -> None:
        if not self._train_feats:
            raise RuntimeError(
                "no training records collected: observe_benign() never "
                f"crossed an epoch boundary (epoch={self.epoch}, "
                f"{self.pkt_count} packets seen) — feed more benign traffic "
                "or lower `epoch`")
        train = np.concatenate(self._train_feats)
        self.net = train_kitnet(train, seed=seed)
        scores = score_kitnet(self.net, train)
        if self.threshold is None:
            self.threshold = float(np.quantile(scores, 1.0 - fpr))
        self._train_feats = []

    # ---- inference phase ----
    def process(self, pkts: Dict[str, np.ndarray]
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Returns (record_indices, rmse_scores, alarms)."""
        assert self.net is not None, "call fit() first"
        feats = self._fc(pkts)
        idx = epoch_indices(len(feats), self.epoch, self.pkt_count)
        self.pkt_count += len(feats)
        if not len(idx):
            return idx, np.zeros((0,)), np.zeros((0,), bool)
        scores = score_kitnet(self.net, feats[idx])
        return idx, scores, scores > self.threshold
