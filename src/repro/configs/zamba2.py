"""zamba2-2.7b — [hybrid] 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64. Mamba2 backbone + shared attention block applied
every 6 layers (9 applications, shared weights). [arXiv:2411.15242; hf]

long_500k note: the shared attention runs with a 4096 sliding window in the
long-context cell (see launch/dryrun.py), keeping decode sub-quadratic; the
Mamba2 layers carry the long-range state.
"""
from repro.configs.base import ArchConfig, HYBRID

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family=HYBRID,
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_chunk=128,
    attn_every=6,
)
