"""granite-20b — [dense] 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152. llama-arch, code. [arXiv:2405.04324; hf]"""
from repro.configs.base import ArchConfig, DENSE

CONFIG = ArchConfig(
    name="granite-20b",
    family=DENSE,
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    act="gelu_tanh",
    gated_mlp=False,
)
