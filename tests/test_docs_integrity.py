"""Docs-integrity: every ``DESIGN.md §N`` and ``docs/…`` reference in the
tree resolves to an existing file/section.  This is the CI step that keeps
DESIGN.md honest — a citation to a missing section fails the build."""
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

# the §N may be separated from "DESIGN.md" by a comment-wrapped line break
# ("... DESIGN.md\n# §2"), so allow comment/whitespace between the two
_DESIGN_REF = re.compile(r"DESIGN\.md(?:[\s#*>]*§(\d+))?")
_DOCS_REF = re.compile(r"\bdocs/[\w./-]+\.md\b")
_SECTION = re.compile(r"^##\s+§(\d+)\b", re.M)


def _scan_files():
    yield from ROOT.joinpath("src").rglob("*.py")
    yield from ROOT.joinpath("benchmarks").rglob("*.py")
    yield from ROOT.joinpath("examples").rglob("*.py")
    for md in ("README.md",):
        p = ROOT / md
        if p.exists():
            yield p
    if (ROOT / "docs").is_dir():
        yield from ROOT.joinpath("docs").rglob("*.md")


def test_design_md_exists_with_cited_sections():
    refs = []   # (file, section or None)
    for f in _scan_files():
        for m in _DESIGN_REF.finditer(f.read_text()):
            refs.append((str(f.relative_to(ROOT)), m.group(1)))
    assert refs, "expected DESIGN.md citations in the tree"
    design = ROOT / "DESIGN.md"
    assert design.exists(), \
        f"DESIGN.md is cited {len(refs)} times but does not exist"
    sections = set(_SECTION.findall(design.read_text()))
    dangling = sorted({(f, n) for f, n in refs
                       if n is not None and n not in sections})
    assert not dangling, \
        f"dangling DESIGN.md § citations (have §{sorted(sections)}): {dangling}"


def test_docs_references_exist():
    dangling = []
    for f in _scan_files():
        for m in _DOCS_REF.finditer(f.read_text()):
            if not (ROOT / m.group(0)).exists():
                dangling.append((str(f.relative_to(ROOT)), m.group(0)))
    assert not dangling, f"references to missing docs/ files: {dangling}"


def test_architecture_doc_names_real_modules():
    """docs/ARCHITECTURE.md's module map must not drift from the tree."""
    arch = ROOT / "docs" / "ARCHITECTURE.md"
    assert arch.exists()
    text = arch.read_text()
    missing = []
    for m in re.finditer(r"`((?:core|detection|serving|kernels|traffic)"
                         r"/[\w/]+\.py)`", text):
        if not (ROOT / "src" / "repro" / m.group(1)).exists():
            missing.append(m.group(1))
    assert not missing, f"ARCHITECTURE.md names missing modules: {missing}"
