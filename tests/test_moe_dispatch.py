"""§Perf cell-A optimization: shard_map local MoE dispatch must match the
dense global-view dispatch exactly (forward) and in gradients, on a real
(2,4) host-device mesh."""
import json
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_local_dispatch_matches_dense():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    code = textwrap.dedent("""
        import dataclasses, json
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.configs import get_arch, reduced
        from repro.models import moe as moe_mod
        from repro.distributed import flags
        from repro.distributed.sharding import use_rules, set_mesh

        cfg = dataclasses.replace(
            reduced(get_arch("kimi-k2-1t-a32b")),
            n_experts=8, top_k=2, capacity_factor=8.0, n_shared_experts=1)
        key = jax.random.PRNGKey(0)
        p = moe_mod.moe_init(key, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model)) * 0.5
        y_ref, _ = moe_mod.moe_ffn(p, x, cfg)

        def loss(pp, xx):
            y, aux = moe_mod.moe_ffn(pp, xx, cfg)
            return jnp.sum(y ** 2) + 0.01 * aux
        g_ref = jax.grad(loss)(p, x)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = {"batch": ("data",), "experts": "model",
                 "expert_cap": ("data",), "ff": None, "fsdp": None}
        pspec = {"router": P(), "wi": P("model", None, None),
                 "wg": P("model", None, None), "wo": P("model", None, None),
                 "shared": {"wi": P(), "wg": P(), "wo": P()}}
        with use_rules(rules), \\
             flags.use_local_moe_dispatch(mesh, ("data",), "model"), \\
             set_mesh(mesh):
            p_sh = jax.tree_util.tree_map(
                lambda v, s: jax.device_put(v, NamedSharding(mesh, s)),
                p, pspec)
            x_sh = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
            y_loc, _ = jax.jit(lambda a, b: moe_mod.moe_ffn(a, b, cfg))(p_sh, x_sh)
            g_loc = jax.jit(jax.grad(loss))(p_sh, x_sh)
        ferr = float(jnp.max(jnp.abs(y_loc - y_ref)))
        gerr = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
            jax.tree_util.tree_leaves(g_loc), jax.tree_util.tree_leaves(g_ref)))
        print(json.dumps({"ferr": ferr, "gerr": gerr}))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ferr"] < 1e-4, res
    assert res["gerr"] < 1e-3, res
