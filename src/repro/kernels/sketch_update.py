"""Count-Min sketch flow-state update as a Pallas kernel.

The sketch analogue of ``feature_update._fc_full_kernel``: one grid step
processes a chunk of packets with ALL sketch tables resident in VMEM; an
in-kernel ``fori_loop`` applies, per packet and per key type:

    hash rows (host-precomputed indices) -> gather the R hashed cells
    -> decay to now -> per-atom min across rows (the Count-Min read)
    -> conservative update (raise each cell to min+increment, never past
       its own decayed value) -> statistics -> scatter the R cells back

Table layout mirrors the dense full kernel's flattening: the sketch's
(key, row, width[, dir]) axes collapse into one row axis so every access
is a ``pl.ds(row, 1)`` dynamic slice on a (rows_total, N_DECAY) ref —
uni atoms ``(N_UNI·R·W, ND)``, direction-paired bi atoms
``(N_BI·R·W·2, ND)``, channel SR state ``(N_BI·R·W, ND)``.  Row indices
are precomputed host-side (vectorised hashing), so the kernel never
hashes; ``evict_age`` rides along as a (1, 1) scalar ref.

The R-row loop is STATICALLY unrolled (R is a shape constant, typically
2-8), so on TPU each packet costs R dynamic-slice gathers + a vector
min/max chain per key type — no data-dependent control flow.

Semantics are ``core/sketch.process_sketch`` (the pure-JAX reference);
parity is pinned in tests/test_state_backends.py.  Exact arithmetic only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.state import LAMBDAS, N_BI, N_DECAY, N_FEATURES, N_UNI
from repro.kernels.feature_update import _BLOCKED_TO_ORACLE, _safe_div

_LAM = tuple(LAMBDAS)


def _sketch_kernel(lam_ref, age_ref,
                   urow_ref, brow_o_ref, brow_p_ref, brow_s_ref,
                   ts_ref, len_ref,
                   ult_i, uw_i, uls_i, uss_i,
                   blt_i, bw_i, bls_i, bss_i, brl_i, bsr_i, bslt_i, bsw_i,
                   ult, uw, uls, uss,
                   blt, bw, bls, bss, brl, bsr, bslt, bsw,
                   stats_ref, *, chunk: int, n_pkts: int, rows: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _copy_in():
        for src, dst in ((ult_i, ult), (uw_i, uw), (uls_i, uls), (uss_i, uss),
                         (blt_i, blt), (bw_i, bw), (bls_i, bls), (bss_i, bss),
                         (brl_i, brl), (bsr_i, bsr), (bslt_i, bslt),
                         (bsw_i, bsw)):
            dst[...] = src[...]

    lam = lam_ref[...]                                  # (1, N_DECAY)
    age = age_ref[0, 0]

    def _minimum(vals):
        m = vals[0]
        for v in vals[1:]:
            m = jnp.minimum(m, v)
        return m

    def _cu(lt_tab, w_tab, ls_tab, ss_tab, rws, t, x):
        """Gather R cells, decay, Count-Min estimate + conservative
        update.  Returns (per-row updated atoms, per-atom estimates)."""
        cand = {"w": [], "ls": [], "ss": []}
        for row in rws:
            lt = lt_tab[pl.ds(row, 1), :]
            dt = jnp.maximum(t - lt, 0.0)
            dead = (lt < 0.0) | ((age > 0.0) & (dt > age))
            delta = jnp.where(dead, 0.0, jnp.exp2(-lam * dt))
            # candidate-only formulation (see core/sketch._cu_update):
            # a second use of the raw product v·δ would block the fma
            # contraction the serial oracle's expression gets
            cand["w"].append(w_tab[pl.ds(row, 1), :] * delta + 1.0)
            cand["ls"].append(ls_tab[pl.ds(row, 1), :] * delta + x)
            cand["ss"].append(ss_tab[pl.ds(row, 1), :] * delta + x * x)
        ew, els, ess = (_minimum(cand[k]) for k in ("w", "ls", "ss"))
        upd = [(jnp.maximum(cand["w"][r] - 1.0, ew),
                jnp.maximum(cand["ls"][r] - x, els),
                jnp.maximum(cand["ss"][r] - x * x, ess))
               for r in range(rows)]
        return upd, (ew, els, ess)

    def _stats(w, ls, ss):
        mu = _safe_div(ls, w)
        var = jnp.abs(_safe_div(ss, w) - mu * mu)
        return mu, var, jnp.sqrt(var)

    def body(i, _):
        g = step * chunk + i
        valid = g < n_pkts
        t = ts_ref[i]
        x = len_ref[i]
        pieces = []

        # ---- unidirectional key types ----
        for ki in range(N_UNI):
            rws = [urow_ref[i, ki * rows + r] for r in range(rows)]
            upd, (ew, els, ess) = _cu(ult, uw, uls, uss, rws, t, x)
            mu, var, sig = _stats(ew, els, ess)
            pieces += [ew, mu, sig]
            for r, row in enumerate(rws):
                w2, ls2, ss2 = upd[r]

                @pl.when(valid)
                def _store_uni(row=row, w2=w2, ls2=ls2, ss2=ss2):
                    ult[pl.ds(row, 1), :] = jnp.full_like(w2, t)
                    uw[pl.ds(row, 1), :] = w2
                    uls[pl.ds(row, 1), :] = ls2
                    uss[pl.ds(row, 1), :] = ss2

        # ---- bidirectional key types ----
        for ki in range(N_BI):
            orws = [brow_o_ref[i, ki * rows + r] for r in range(rows)]
            prws = [brow_p_ref[i, ki * rows + r] for r in range(rows)]
            srws = [brow_s_ref[i, ki * rows + r] for r in range(rows)]

            upd, (ew_o, els_o, ess_o) = _cu(blt, bw, bls, bss, orws, t, x)
            mu_o, var_o, sig_o = _stats(ew_o, els_o, ess_o)

            # stale opposite-direction stats: stored values, aged cells
            # read as empty, Count-Min min across rows
            wp, lsp, ssp = [], [], []
            for prow in prws:
                lt_p = blt[pl.ds(prow, 1), :]
                zap = (age > 0.0) & ((t - lt_p) > age)
                z = lambda tab: jnp.where(zap, 0.0, tab[pl.ds(prow, 1), :])
                wp.append(z(bw))
                lsp.append(z(bls))
                ssp.append(z(bss))
            w_p, ls_p, ss_p = _minimum(wp), _minimum(lsp), _minimum(ssp)
            mu_p, var_p, sig_p = _stats(w_p, ls_p, ss_p)

            # SR per row; emit the row with the least conservative
            # channel count (running strict-< select == first argmin)
            r_res = x - mu_o
            sr2s, sw2s = [], []
            for prow, srow in zip(prws, srws):
                sr = bsr[pl.ds(srow, 1), :]
                sr_lt = bslt[pl.ds(srow, 1), :]
                dt_sr = jnp.maximum(t - sr_lt, 0.0)
                evict = (age > 0.0) & (dt_sr > age)
                dsr = jnp.where((sr_lt < 0.0) | evict, 0.0,
                                jnp.exp2(-lam * dt_sr))
                r_opp = jnp.where(evict, 0.0, brl[pl.ds(prow, 1), :])
                sr2s.append(sr * dsr + r_res * r_opp)
                sw2s.append(bsw[pl.ds(srow, 1), :] * dsr)
            m_sw = _minimum(sw2s)
            sw2s = [jnp.maximum(v, m_sw + 1.0) for v in sw2s]
            sr_sel, sw_min = sr2s[0], sw2s[0]
            for r in range(1, rows):
                take = sw2s[r] < sw_min
                sw_min = jnp.where(take, sw2s[r], sw_min)
                sr_sel = jnp.where(take, sr2s[r], sr_sel)

            mag = jnp.sqrt(mu_o * mu_o + mu_p * mu_p)
            rad = jnp.sqrt(var_o * var_o + var_p * var_p)
            cov = _safe_div(sr_sel, ew_o + w_p)
            pcc = _safe_div(cov, sig_o * sig_p)
            pieces += [ew_o, mu_o, sig_o, mag, rad, cov, pcc]

            for r in range(rows):
                orow, srow = orws[r], srws[r]
                w2, ls2, ss2 = upd[r]
                sr2, sw2 = sr2s[r], sw2s[r]

                @pl.when(valid)
                def _store_bi(orow=orow, srow=srow, w2=w2, ls2=ls2,
                              ss2=ss2, sr2=sr2, sw2=sw2):
                    blt[pl.ds(orow, 1), :] = jnp.full_like(w2, t)
                    bw[pl.ds(orow, 1), :] = w2
                    bls[pl.ds(orow, 1), :] = ls2
                    bss[pl.ds(orow, 1), :] = ss2
                    brl[pl.ds(orow, 1), :] = r_res
                    bsr[pl.ds(srow, 1), :] = sr2
                    bslt[pl.ds(srow, 1), :] = jnp.full_like(w2, t)
                    bsw[pl.ds(srow, 1), :] = sw2

        row_stats = jnp.concatenate(pieces, axis=-1)    # (1, N_FEATURES)

        @pl.when(valid)
        def _store_stats():
            stats_ref[pl.ds(i, 1), :] = row_stats

        return 0

    jax.lax.fori_loop(0, chunk, body, 0)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret", "n", "rows"))
def _sketch_call(tables, age, urow, brow_o, brow_p, brow_s, ts, lens, *,
                 chunk: int, interpret: bool, n: int, rows: int):
    n_pad = urow.shape[0]
    nc = n_pad // chunk
    rows_u = tables["ult"].shape[0]
    rows_b = tables["blt"].shape[0]
    rows_s = tables["bsr"].shape[0]

    kernel = functools.partial(_sketch_kernel, chunk=chunk, n_pkts=n,
                               rows=rows)
    spec_u = pl.BlockSpec((rows_u, N_DECAY), lambda s: (0, 0))
    spec_b = pl.BlockSpec((rows_b, N_DECAY), lambda s: (0, 0))
    spec_s = pl.BlockSpec((rows_s, N_DECAY), lambda s: (0, 0))
    spec_idx = pl.BlockSpec((chunk, 2 * rows), lambda s: (s, 0))
    spec_pkt = pl.BlockSpec((chunk,), lambda s: (s,))
    tab_specs = [spec_u] * 4 + [spec_b] * 5 + [spec_s] * 3
    tab_shapes = ([jax.ShapeDtypeStruct((rows_u, N_DECAY), jnp.float32)] * 4 +
                  [jax.ShapeDtypeStruct((rows_b, N_DECAY), jnp.float32)] * 5 +
                  [jax.ShapeDtypeStruct((rows_s, N_DECAY), jnp.float32)] * 3)

    out = pl.pallas_call(
        kernel,
        grid=(nc,),
        in_specs=[pl.BlockSpec((1, N_DECAY), lambda s: (0, 0)),
                  pl.BlockSpec((1, 1), lambda s: (0, 0)),
                  spec_idx, spec_idx, spec_idx, spec_idx,
                  spec_pkt, spec_pkt] + tab_specs,
        out_specs=tab_specs + [
            pl.BlockSpec((chunk, N_FEATURES), lambda s: (s, 0))],
        out_shape=tab_shapes + [
            jax.ShapeDtypeStruct((n_pad, N_FEATURES), jnp.float32)],
        input_output_aliases={8 + k: k for k in range(12)},
        interpret=interpret,
    )(jnp.asarray(_LAM, jnp.float32)[None, :],
      age.reshape(1, 1).astype(jnp.float32),
      urow, brow_o, brow_p, brow_s, ts, lens,
      tables["ult"], tables["uw"], tables["uls"], tables["uss"],
      tables["blt"], tables["bw"], tables["bls"], tables["bss"],
      tables["brl"], tables["bsr"], tables["bslt"], tables["bsw"])
    stats = out[-1][:n]
    names = ("ult", "uw", "uls", "uss", "blt", "bw", "bls", "bss",
             "brl", "bsr", "bslt", "bsw")
    return dict(zip(names, out[:-1])), stats


def sketch_update_full(state, pkts, *, chunk: int = 256,
                       interpret: bool = True):
    """Full sketch-state FC (all 80 features) as one Pallas pipeline.

    state: an ``init_state(..., state_backend="sketch")`` dict.  Returns
    ``(new_state, feats (n, N_FEATURES))`` matching the pure-JAX
    reference ``core/sketch.process_sketch`` to float tolerance.
    """
    from repro.core.sketch import sketch_packet_rows, sketch_rows, \
        sketch_width

    R, W = sketch_rows(state), sketch_width(state)
    sl = sketch_packet_rows(pkts, R, W)
    ts = pkts["ts"].astype(jnp.float32)
    lens = pkts["length"].astype(jnp.float32)
    n = ts.shape[0]

    # host-side flattened row precomputation: uni row (k·R+r)·W + col,
    # bi-direction row (…)·2 + d, channel row (k·R+r)·W + col
    key_off = (jnp.arange(N_UNI, dtype=jnp.int32) * R)[:, None] \
        + jnp.arange(R, dtype=jnp.int32)[None, :]               # (K, R)
    ucols = jnp.stack([sl["src_mac_ip"], sl["src_ip"]], 1)      # (n, K, R)
    urow = (key_off[None] * W + ucols).reshape(n, -1)
    bcols = jnp.stack([sl["channel"], sl["socket"]], 1)
    bbase = (key_off[None] * W + bcols).reshape(n, -1)          # (n, K·R)
    d = sl["dir"][:, None]
    brow_o = bbase * 2 + d
    brow_p = bbase * 2 + (1 - d)
    brow_s = bbase

    nc = -(-max(n, 1) // chunk)
    n_pad = nc * chunk
    pad2 = lambda a: jnp.pad(a, ((0, n_pad - n), (0, 0)))
    pad1 = lambda a: jnp.pad(a, (0, n_pad - n))
    uni, bi = state["uni"], state["bi"]
    tables = {
        "ult": uni["last_t"].reshape(-1, N_DECAY),
        "uw": uni["w"].reshape(-1, N_DECAY),
        "uls": uni["ls"].reshape(-1, N_DECAY),
        "uss": uni["ss"].reshape(-1, N_DECAY),
        "blt": bi["last_t"].reshape(-1, N_DECAY),
        "bw": bi["w"].reshape(-1, N_DECAY),
        "bls": bi["ls"].reshape(-1, N_DECAY),
        "bss": bi["ss"].reshape(-1, N_DECAY),
        "brl": bi["res_last"].reshape(-1, N_DECAY),
        "bsr": bi["sr"].reshape(-1, N_DECAY),
        "bslt": bi["sr_last_t"].reshape(-1, N_DECAY),
        "bsw": bi["sw"].reshape(-1, N_DECAY),
    }
    new_tab, stats = _sketch_call(
        tables, state["evict_age"], pad2(urow), pad2(brow_o), pad2(brow_p),
        pad2(brow_s), pad1(ts), pad1(lens), chunk=chunk,
        interpret=interpret, n=n, rows=R)

    feats = jnp.take(stats, jnp.asarray(_BLOCKED_TO_ORACLE), axis=1)
    sh_u = (N_UNI, R, W, N_DECAY)
    sh_b = (N_BI, R, W, 2, N_DECAY)
    sh_s = (N_BI, R, W, N_DECAY)
    new_state = {
        "uni": {"last_t": new_tab["ult"].reshape(sh_u),
                "w": new_tab["uw"].reshape(sh_u),
                "ls": new_tab["uls"].reshape(sh_u),
                "ss": new_tab["uss"].reshape(sh_u)},
        "bi": {"last_t": new_tab["blt"].reshape(sh_b),
               "w": new_tab["bw"].reshape(sh_b),
               "ls": new_tab["bls"].reshape(sh_b),
               "ss": new_tab["bss"].reshape(sh_b),
               "res_last": new_tab["brl"].reshape(sh_b),
               "sr": new_tab["bsr"].reshape(sh_s),
               "sr_last_t": new_tab["bslt"].reshape(sh_s),
               "sw": new_tab["bsw"].reshape(sh_s)},
        "evict_age": state["evict_age"],
    }
    return new_state, feats
