"""Serving launcher: either the Peregrine detection service over a synthetic
packet stream, or LM serving with batched requests.

  PYTHONPATH=src python -m repro.launch.serve --mode detect --attack mirai
  PYTHONPATH=src python -m repro.launch.serve --mode lm --arch gemma2-2b
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced as reduce_cfg
from repro.models import build_model


def serve_detect(args):
    from repro.detection.metrics import auc
    from repro.serving import DetectionService
    from repro.traffic import synth_trace

    data = synth_trace(args.attack, n_train=args.n_train,
                       n_benign_eval=args.n_eval // 2,
                       n_attack=args.n_eval // 2, seed=0)
    svc = DetectionService(epoch=args.epoch, mode=args.fc_mode)
    t0 = time.time()
    svc.observe_stream(data["train"], chunk=8192)
    svc.fit(fpr=0.01)
    print(f"trained on {svc.pkt_count} pkts in {time.time() - t0:.1f}s; "
          f"threshold={svc.threshold:.4f}")
    t0 = time.time()
    # record indices are global stream positions; the eval window starts at
    # the current packet count
    eval_start = svc.pkt_count
    idx, scores, alarms = svc.process_stream(data["eval"], chunk=8192)
    dt = time.time() - t0
    labels = data["eval"]["label"][idx - eval_start]
    n = len(data["eval"]["ts"])
    print(f"processed {n} pkts in {dt:.1f}s ({n / dt:.0f} pps on-CPU), "
          f"{len(scores)} records, {int(alarms.sum())} alarms, "
          f"AUC={auc(scores, labels):.3f}")


def serve_lm(args):
    from repro.models.lm_engine import Request, ServeEngine

    cfg = reduce_cfg(get_arch(args.arch)) if args.reduced else get_arch(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_slots=args.slots, max_seq=256)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = jnp.asarray(rng.integers(1, cfg.vocab, size=16), jnp.int32)
        eng.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))
    t0 = time.time()
    outputs = eng.run()
    dt = time.time() - t0
    toks = sum(len(v) for v in outputs.values())
    print(f"served {len(outputs)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s on-CPU)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("detect", "lm"), default="detect")
    ap.add_argument("--attack", default="mirai")
    ap.add_argument("--epoch", type=int, default=1024)
    ap.add_argument("--fc-mode", default="exact", choices=("exact", "switch"))
    ap.add_argument("--n-train", type=int, default=20000)
    ap.add_argument("--n-eval", type=int, default=20000)
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    if args.mode == "detect":
        serve_detect(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
