"""Peregrine feature-computation pipeline — serial (switch-semantics oracle).

``process_serial`` applies packets one at a time via lax.scan, mirroring the
per-packet MAU pipeline of the switch:

  decay feature atoms -> update atoms -> compute statistics -> emit features

Two fidelity modes:
  * ``exact``  — real mul/div/sqrt, all 4 decay instances updated per packet.
  * ``switch`` — shift-approximated arithmetic (arith.py), math-unit sqrt,
    and the paper's round-robin decay handling: a single decay instance
    updated per packet (Figure 5), with iterated-halving quantised decay.

The parallel TPU-native implementation (core/parallel.py) is validated
against ``exact`` mode of this oracle; the Pallas kernel
(kernels/feature_update) is validated against both.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import arith
from repro.core.state import (
    BI_KEYS, BI_STATS, LAMBDAS, N_BI, N_DECAY, N_FEATURES, N_UNI, UNI_KEYS,
    UNI_STATS, init_state, packet_slots,
)

_LAM = jnp.asarray(LAMBDAS, jnp.float32)           # (4,)


def _decay_all(lam: jax.Array, dt: jax.Array, mode: str) -> jax.Array:
    if mode == "switch":
        k = jnp.clip(jnp.floor(lam * jnp.maximum(dt, 0.0)), 0.0, 31.0)
        return jnp.exp2(-k)
    return jnp.exp2(-lam * jnp.maximum(dt, 0.0))


def _stream_update(last_t, w, ls, ss, rr, t, length, mode: str):
    """Decay + update one stream's atoms (vectorised over leading dims).

    last_t/w/ls/ss: (..., N_DECAY); rr: (...,) int32; t, length: (...,).
    Returns new (last_t, w, ls, ss, rr).
    """
    dt = jnp.maximum(t[..., None] - last_t, 0.0)
    fresh = last_t < 0.0                            # never seen
    delta = jnp.where(fresh, 0.0, _decay_all(_LAM, dt, mode))
    if mode == "switch":
        # round-robin: only instance rr is decayed+updated this packet
        upd = jax.nn.one_hot(rr % N_DECAY, N_DECAY, dtype=jnp.float32)
        new_rr = rr + 1
    else:
        upd = jnp.ones_like(delta)
        new_rr = rr
    dec = (lambda v: jnp.floor(v)) if mode == "switch" else (lambda v: v)
    w2 = jnp.where(upd > 0, dec(w * delta) + 1.0, w)
    ls2 = jnp.where(upd > 0, dec(ls * delta) + length[..., None], ls)
    ss2 = jnp.where(upd > 0, dec(ss * delta) + length[..., None] ** 2, ss)
    lt2 = jnp.where(upd > 0, jnp.broadcast_to(t[..., None], last_t.shape), last_t)
    return lt2, w2, ls2, ss2, new_rr


def _stream_stats(w, ls, ss, mode: str):
    """(mu, var, sigma) per decay instance."""
    mu = arith.div(ls, w, mode)
    ex2 = arith.div(ss, w, mode)
    var = jnp.abs(ex2 - arith.square(mu, mode))
    sigma = arith.sqrt(var, mode)
    return mu, var, sigma


def _packet_step(state: Dict, pkt, mode: str):
    """Process one packet. pkt: dict of scalars (slots precomputed)."""
    t, length = pkt["ts"], pkt["length"]
    feats = []

    # ---- unidirectional keys ----
    uni = state["uni"]
    ki = jnp.arange(N_UNI)
    slots = jnp.stack([pkt["src_mac_ip"], pkt["src_ip"]])      # (2,)
    g = lambda a: a[ki, slots]                                 # (2, N_DECAY)
    lt, w, ls, ss, rr = (g(uni["last_t"]), g(uni["w"]), g(uni["ls"]),
                         g(uni["ss"]), uni["rr"][ki, slots])
    tb = jnp.broadcast_to(t, (N_UNI,))
    lb = jnp.broadcast_to(length, (N_UNI,))
    lt, w, ls, ss, rr = _stream_update(lt, w, ls, ss, rr, tb, lb, mode)
    mu, var, sigma = _stream_stats(w, ls, ss, mode)
    feats.append(jnp.stack([w, mu, sigma], axis=-1).reshape(-1))  # (2*4*3,)
    s = lambda name, v: uni[name].at[ki, slots].set(v)
    state = {**state, "uni": {"last_t": s("last_t", lt), "w": s("w", w),
                              "ls": s("ls", ls), "ss": s("ss", ss),
                              "rr": uni["rr"].at[ki, slots].set(rr)}}

    # ---- bidirectional keys ----
    bi = state["bi"]
    kb = jnp.arange(N_BI)
    bslots = jnp.stack([pkt["channel"], pkt["socket"]])        # (2,)
    d = pkt["dir"]
    o = 1 - d
    gb = lambda a: a[kb, bslots]                               # (2, 2, N_DECAY)
    lt_b, w_b, ls_b, ss_b = (gb(bi["last_t"]), gb(bi["w"]), gb(bi["ls"]),
                             gb(bi["ss"]))
    rr_b = bi["rr"][kb, bslots]
    # update own-direction stream
    own = lambda a: a[kb, d]                                   # (2, N_DECAY)
    lt_o, w_o, ls_o, ss_o, rr_o = _stream_update(
        own(lt_b), own(w_b), own(ls_b), own(ss_b), rr_b,
        jnp.broadcast_to(t, (N_BI,)), jnp.broadcast_to(length, (N_BI,)), mode)
    mu_o, var_o, sig_o = _stream_stats(w_o, ls_o, ss_o, mode)
    # opposite-direction stats (stored values — stale, as on the switch)
    opp = lambda a: a[kb, o]
    mu_p, var_p, sig_p = _stream_stats(opp(w_b), opp(ls_b), opp(ss_b), mode)

    # SR update (decayed sum of residual products, §Table 2)
    sr = bi["sr"][kb, bslots]
    sr_lt = bi["sr_last_t"][kb, bslots]
    res_last = bi["res_last"][kb, bslots]                      # (2, 2, N_DECAY)
    r = length - mu_o                                          # (2, N_DECAY)
    dt_sr = jnp.maximum(t - sr_lt, 0.0)
    dsr = jnp.where(sr_lt < 0, 0.0, _decay_all(_LAM, dt_sr, mode))
    r_opp = res_last[kb, o]                                    # (2, N_DECAY)
    sr2 = sr * dsr + r * r_opp
    res_last2 = res_last.at[kb, d].set(r)

    # bidirectional statistics
    mag = arith.sqrt(arith.square(mu_o, mode) + arith.square(mu_p, mode), mode)
    rad = arith.sqrt(arith.square(var_o, mode) + arith.square(var_p, mode), mode)
    cov = arith.div(sr2, w_o + opp(w_b), mode)
    denom = (arith.shift_mul(sig_o, sig_p) if mode == "switch"
             else sig_o * sig_p)
    pcc = arith.div(cov, denom, mode)
    feats.append(jnp.stack([w_o, mu_o, sig_o, mag, rad, cov, pcc],
                           axis=-1).reshape(-1))               # (2*4*7,)

    sb = lambda name, v: bi[name].at[kb, bslots].set(v)
    lt_b2 = lt_b.at[kb, d].set(lt_o)
    w_b2 = w_b.at[kb, d].set(w_o)
    ls_b2 = ls_b.at[kb, d].set(ls_o)
    ss_b2 = ss_b.at[kb, d].set(ss_o)
    state = {**state, "bi": {
        "last_t": sb("last_t", lt_b2), "w": sb("w", w_b2),
        "ls": sb("ls", ls_b2), "ss": sb("ss", ss_b2),
        "sr": bi["sr"].at[kb, bslots].set(sr2),
        "sr_last_t": bi["sr_last_t"].at[kb, bslots].set(
            jnp.broadcast_to(t, (N_BI, N_DECAY))),
        "res_last": sb("res_last", res_last2),
        "rr": bi["rr"].at[kb, bslots].set(rr_o),
    }}
    features = jnp.concatenate(feats)                          # (N_FEATURES,)
    return state, features


@partial(jax.jit, static_argnames=("mode",))
def process_serial(state: Dict, pkts: Dict[str, jax.Array],
                   mode: str = "exact") -> Tuple[Dict, jax.Array]:
    """Sequential per-packet processing (switch semantics).

    pkts: arrays of shape (n,). Returns (new_state, features (n, N_FEATURES)).
    """
    from repro.core.state import state_slots
    n_slots = state_slots(state)
    slots = packet_slots(pkts, n_slots)
    xs = {"ts": pkts["ts"].astype(jnp.float32),
          "length": pkts["length"].astype(jnp.float32), **slots}
    tables = {k: state[k] for k in ("uni", "bi")}

    def step(tb, x):
        st, f = _packet_step(tb, x, mode)
        return {k: st[k] for k in ("uni", "bi")}, f

    tables, feats = jax.lax.scan(step, tables, xs)
    return tables, feats
