"""State-backend abstraction: dense/sketch registry, Count-Min semantics,
and the acceptance invariants of the pluggable flow-table layer.

The load-bearing claims (DESIGN.md §11):

* the ``dense`` registry entry is the pre-registry ``init_state`` —
  bit-for-bit, so no dense caller can have moved;
* a ``rows=1`` sketch of equal width hashes flows to exactly the dense
  slots (row 0 keeps the dense salt) and its STATE UPDATE degenerates to
  the dense serial oracle bit-for-bit; the emitted sigma/magnitude/radius
  statistics — pure outputs that never feed state — agree to float
  rounding only (XLA contracts the variance expression differently in the
  two scan bodies; same tolerance family as the segmented-scan backend);
* the Pallas sketch kernel reproduces the pure-JAX reference;
* Count-Min with conservative update never under-estimates the decayed
  packet count;
* eviction (``evict_age``) makes idle cells read as empty;
* fixed-size sketch state absorbs a stream with ~1M distinct flows
  through BOTH deployment paths (fused service + multi-tenant engine).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FEATURE_NAMES, N_FEATURES, compute_features,
                        init_state)
from repro.core.state import (KEY_SALTS, StatePool, available_state_backends,
                              hash_fields, init_state_stacked, np_hash_fields,
                              slot_collisions, state_backend_of, state_config,
                              state_slots, state_spec_of, _np_key_fields)
from repro.core.sketch import row_salt, sketch_packet_rows
from repro.traffic.generator import ATTACKS, benign_trace, to_jnp

N_PKTS = 256
N_SLOTS = 512

# cov/pcc divide by near-cancelling variances; std/radius are sqrts of the
# same cancellation — the columns where fp reassociation shows up as O(0.1)
# abs on O(1e5) inputs (cf. tests/test_backends.py's scan tolerance)
_LOOSE = np.array([i for i, nm in enumerate(FEATURE_NAMES)
                   if nm.endswith((":cov", ":pcc", ":radius", ":std"))])
_TIGHT = np.setdiff1d(np.arange(N_FEATURES), _LOOSE)


def _trace(attack: str, seed: int = 0):
    rng = np.random.default_rng(seed)
    ben = benign_trace(160, 6.0, rng)
    atk = ATTACKS[attack](120, 1.0, 5.0, rng)
    out = {k: np.concatenate([ben[k], atk[k]]) for k in ben}
    order = np.argsort(out["ts"], kind="stable")
    out = {k: v[order][:N_PKTS] for k, v in out.items()}
    return {k: jnp.asarray(v) for k, v in out.items() if k != "label"}


def _flow_trace(n: int, seed: int = 0):
    """n packets, every one a NEW flow under all four key types."""
    rng = np.random.default_rng(seed)
    return {
        "ts": (np.arange(n) * 1e-4).astype(np.float32),
        "src": np.arange(1, n + 1, dtype=np.uint32),
        "dst": np.full(n, 0xC0A80001, np.uint32),
        "sport": (np.arange(n, dtype=np.uint32) % 60000 + 1024
                  ).astype(np.uint32),
        "dport": np.full(n, 80, np.uint32),
        "proto": np.full(n, 6, np.uint32),
        "length": rng.integers(60, 1500, n).astype(np.float32),
    }


def _assert_feats_close(got, want, msg=""):
    """Rounding-only feature agreement: tight everywhere except the
    variance-cancellation columns, which get the abs slack their O(1e5)
    inputs imply."""
    got, want = np.asarray(got), np.asarray(want)
    d = np.abs(got - want)
    ok_t = d[:, _TIGHT] <= 1e-3 + 1e-4 * np.abs(want[:, _TIGHT])
    ok_l = d[:, _LOOSE] <= 0.5 + 1e-3 * np.abs(want[:, _LOOSE])
    assert ok_t.all(), (msg, d[:, _TIGHT].max())
    assert ok_l.all(), (msg, d[:, _LOOSE].max())


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_dense_is_the_default_bitwise():
    assert {"dense", "sketch"} <= set(available_state_backends())
    a = init_state(N_SLOTS)
    b = init_state(N_SLOTS, state_backend="dense")
    assert jax.tree_util.tree_structure(a) == jax.tree_util.tree_structure(b)
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert state_backend_of(a) == "dense"
    assert state_config(a) == {}
    assert state_slots(a) == N_SLOTS


def test_registry_sketch_identification_and_config():
    s = init_state(64, state_backend="sketch", rows=3, evict_age=2.5)
    assert state_backend_of(s) == "sketch"
    assert state_slots(s) == 64
    assert state_config(s) == {"rows": 3, "evict_age": 2.5}
    assert state_spec_of(s).compute is not None
    # row 0 of every key type keeps the dense salt
    for base in KEY_SALTS.values():
        assert row_salt(base, 0) == base


def test_registry_errors():
    with pytest.raises(ValueError, match="unknown state backend"):
        init_state(64, state_backend="nope")
    with pytest.raises(ValueError, match="at least one row"):
        init_state(64, state_backend="sketch", rows=0)
    pk = _trace("syn_dos")
    with pytest.raises(ValueError, match="sketch-backed state"):
        compute_features(init_state(64), pk, backend="sketch")
    sk = init_state(64, state_backend="sketch", rows=2)
    with pytest.raises(ValueError, match="exact arithmetic only"):
        compute_features(sk, pk, backend="serial", mode="switch")


# ---------------------------------------------------------------------------
# rows=1 degeneracy: the collision-free sizing of the sketch
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("attack", sorted(ATTACKS))
def test_sketch_r1_state_bitwise_dense(attack):
    pk = _trace(attack)
    st_d, f_d = compute_features(init_state(N_SLOTS), pk, backend="serial")
    st_s, f_s = compute_features(
        init_state(N_SLOTS, state_backend="sketch", rows=1), pk)
    for grp in ("uni", "bi"):
        for k in st_d[grp]:
            if k == "rr":           # dense round-robin counter: no sketch twin
                continue
            np.testing.assert_array_equal(
                np.asarray(st_s[grp][k])[:, 0], np.asarray(st_d[grp][k]),
                err_msg=f"{attack}/{grp}/{k}")
    _assert_feats_close(f_s, f_d, attack)


# ---------------------------------------------------------------------------
# Pallas kernel vs pure-JAX reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rows", [1, 3])
def test_sketch_kernel_matches_reference(rows):
    pk = _trace("mirai")
    W = 64                          # small width -> real collisions at R=3
    st0 = init_state(W, state_backend="sketch", rows=rows)
    st_r, f_r = compute_features(
        jax.tree_util.tree_map(jnp.copy, st0), pk)
    st_k, f_k = compute_features(st0, pk, backend="pallas", chunk=64)
    _assert_feats_close(f_k, f_r, f"rows={rows}")
    for grp in ("uni", "bi"):
        for k in st_r[grp]:
            np.testing.assert_allclose(
                np.asarray(st_k[grp][k]), np.asarray(st_r[grp][k]),
                rtol=1e-3, atol=0.1, err_msg=f"rows={rows}/{grp}/{k}")


def test_sketch_kernel_chunked_matches_one_shot():
    pk = _trace("mirai")
    st0 = init_state(64, state_backend="sketch", rows=2)
    _, f_once = compute_features(
        jax.tree_util.tree_map(jnp.copy, st0), pk, backend="pallas",
        chunk=64)
    st = st0
    outs = []
    for i in range(0, N_PKTS, 64):
        chunk = {k: v[i:i + 64] for k, v in pk.items()}
        st, f = compute_features(st, chunk, backend="pallas", chunk=32)
        outs.append(np.asarray(f))
    np.testing.assert_allclose(np.concatenate(outs), np.asarray(f_once),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# Count-Min semantics
# ---------------------------------------------------------------------------
def test_sketch_never_underestimates_decayed_count():
    """Conservative update keeps every estimate one-sided: the w features
    from a heavily-collided sketch are >= the collision-free truth."""
    pk = _trace("ddos_hulk")
    # the truth table must be VERIFIED collision-free — at 2^16 slots this
    # trace still aliases two channels, which fabricates an underestimate
    np_pk = {k: np.asarray(v) for k, v in pk.items()}
    n_true = next(n for n in (1 << 18, 1 << 22, 1 << 26)
                  if slot_collisions(np_pk, n)["total"] == 0)
    _, f_true = compute_features(init_state(n_true), pk, backend="serial")
    _, f_sk = compute_features(
        init_state(16, state_backend="sketch", rows=2), pk)
    w_cols = [i for i, nm in enumerate(FEATURE_NAMES) if nm.endswith(":w")]
    over = np.asarray(f_sk)[:, w_cols] - np.asarray(f_true)[:, w_cols]
    assert (over >= -2e-3).all(), over.min()
    # and the 16-wide sketch genuinely collided (the test has teeth)
    assert (over > 0.5).any()


def test_sketch_eviction_ages_out_idle_cells():
    n = 8
    base = _flow_trace(n)
    base["src"][:] = 7          # ONE flow...
    base["sport"][:] = 5000
    base["ts"][:] = np.arange(n, dtype=np.float32) * 0.25
    base["ts"][-1] += 600.0     # ...idle 10 minutes before its last packet
    # the slowest decay atom (lambda = 1/60) is the only one with mass
    # left after a 10-minute gap — the others read 1.0 either way
    w_col = FEATURE_NAMES.index(f"src_ip:{1 / 60}:w")

    def w_last(evict_age):
        st = init_state(32, state_backend="sketch", rows=2,
                        evict_age=evict_age)
        _, f = compute_features(st, to_jnp(base))
        return float(np.asarray(f)[-1, w_col])

    assert w_last(0.0) > 1.0        # no aging: decayed history survives
    assert w_last(60.0) == 1.0      # aged out: the flow restarts fresh


def test_sketch_packet_rows_row0_is_dense_mapping():
    pk = to_jnp(_flow_trace(64))
    from repro.core.state import packet_slots
    dense = packet_slots(pk, 64)
    rows = sketch_packet_rows(pk, 3, 64)
    for k in KEY_SALTS:
        np.testing.assert_array_equal(np.asarray(rows[k])[:, 0],
                                      np.asarray(dense[k]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(rows["dir"]),
                                  np.asarray(dense["dir"]))


# ---------------------------------------------------------------------------
# serving layers
# ---------------------------------------------------------------------------
def _mixed_trace(n, seed=0):
    rng = np.random.default_rng(seed)
    return {k: np.asarray(v) for k, v in benign_trace(n, 5.0, rng).items()
            if k != "label"}


def test_sketch_fused_service_matches_staged():
    from repro.serving import DetectionService
    tr = _mixed_trace(2048)
    svc = DetectionService(epoch=128, n_slots=256, state_backend="sketch",
                           state_kw={"rows": 2})
    svc.observe_stream({k: v[:1024] for k, v in tr.items()}, chunk=512)
    svc.fit(seed=0)
    ev = {k: v[1024:] for k, v in tr.items()}
    snap = jax.tree_util.tree_map(jnp.copy, svc.state)
    count = svc.pkt_count
    i_f, s_f, a_f = svc.process_stream(ev, chunk=512, fused=True)
    svc.state, svc.pkt_count = snap, count
    i_s, s_s, a_s = svc.process_stream(ev, chunk=512, fused=False)
    np.testing.assert_array_equal(i_f, i_s)
    np.testing.assert_allclose(s_f, s_s, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(a_f, a_s)


def test_sketch_state_pool_lifecycle():
    pool = StatePool(n_tenants=3, n_slots=64, state_backend="sketch",
                     rows=2, evict_age=5.0)
    t = pool.alloc()
    st = pool.read(t)
    assert state_backend_of(st) == "sketch"
    assert state_config(st) == {"rows": 2, "evict_age": 5.0}
    assert state_slots(st) == 64
    pk = to_jnp(_flow_trace(32))
    st2, _ = compute_features(st, pk)
    pool.write(t, st2)
    np.testing.assert_array_equal(np.asarray(pool.read(t)["uni"]["w"]),
                                  np.asarray(st2["uni"]["w"]))
    pool.reset(t)
    assert float(np.asarray(pool.read(t)["uni"]["w"]).max()) == 0.0
    # stacking broadcasts the scalar evict_age leaf per tenant
    stk = init_state_stacked(2, 16, state_backend="sketch", rows=1,
                             evict_age=3.0)
    assert stk["evict_age"].shape == (2,)


def test_engine_inherits_sketch_backend_from_service():
    from repro.serving import DetectionEngine, DetectionService
    tr = _mixed_trace(3072)
    svc = DetectionService(epoch=128, n_slots=128, state_backend="sketch",
                           state_kw={"rows": 2})
    svc.observe_stream({k: v[:2048] for k, v in tr.items()}, chunk=1024)
    svc.fit(seed=0)
    eng = DetectionEngine.from_service(svc, n_tenants=2, chunk=512)
    assert eng.state_backend == "sketch"
    assert eng.state_kw == {"rows": 2, "evict_age": 0.0}
    ev = {k: v[2048:] for k, v in tr.items()}
    t0, t1 = eng.add_tenant(), eng.add_tenant()
    res = eng.run({t0: ev, t1: ev})
    assert len(res[t0][0])          # records flowed
    for a, b in zip(res[t0], res[t1]):      # tenant isolation: same in ->
        np.testing.assert_array_equal(a, b)  # same out
    # sketch states have no per-flow slots to collide
    assert eng.stats()["tenants"][t0]["slot_collisions"] == 0


def test_dense_engine_counts_slot_collisions():
    from repro.serving import DetectionEngine, DetectionService
    tr = _mixed_trace(3072)
    svc = DetectionService(epoch=128, n_slots=32)   # tiny table -> aliasing
    svc.observe_stream({k: v[:2048] for k, v in tr.items()}, chunk=1024)
    svc.fit(seed=0)
    eng = DetectionEngine.from_service(svc, n_tenants=1, chunk=512)
    t = eng.add_tenant()
    eng.run({t: {k: v[2048:] for k, v in tr.items()}})
    assert eng.stats()["tenants"][t]["slot_collisions"] > 0


# ---------------------------------------------------------------------------
# collision telemetry + hash twins
# ---------------------------------------------------------------------------
def test_slot_collisions_endpoints():
    pk = _flow_trace(64)
    # huge table: 64 flows cannot alias
    assert slot_collisions(pk, 1 << 20)["total"] == 0
    # one slot: every distinct key beyond the first collides, per key type
    c1 = slot_collisions(pk, 1)
    fields = _np_key_fields(pk)
    for name, f in fields.items():
        distinct = len(set(zip(*[np.asarray(x) for x in f])))
        assert c1[name] == distinct - 1, name
    assert c1["total"] == sum(c1[k] for k in fields)


def test_hash_uniformity_and_row_independence_seeded():
    """Seeded twin of the tests/test_properties.py hash properties, so
    the invariants stay covered when ``hypothesis`` is absent: slot loads
    within 5 sigma of binomial, and distinct sketch rows agreeing at the
    chance rate."""
    rng = np.random.default_rng(7)
    n, w = 8192, 64
    fields = tuple(rng.integers(0, 2 ** 32, n, dtype=np.uint32)
                   for _ in range(2))
    for salt in KEY_SALTS.values():
        counts = np.bincount(np_hash_fields(fields, salt) % w, minlength=w)
        exp = n / w
        assert np.abs(counts - exp).max() <= 5.0 * np.sqrt(exp), salt
    pk = to_jnp({
        "ts": np.zeros(n, np.float32),
        "src": rng.integers(0, 2 ** 32, n, dtype=np.uint32),
        "dst": rng.integers(0, 2 ** 32, n, dtype=np.uint32),
        "sport": rng.integers(0, 2 ** 16, n, dtype=np.uint32),
        "dport": rng.integers(0, 2 ** 16, n, dtype=np.uint32),
        "proto": np.full(n, 6, np.uint32),
        "length": np.full(n, 100, np.float32),
    })
    cols = sketch_packet_rows(pk, 3, w)
    for key in ("src_ip", "channel", "socket"):
        c = np.asarray(cols[key])
        for i in range(3):
            for j in range(i + 1, 3):
                assert (c[:, i] == c[:, j]).mean() < 4.0 / w, (key, i, j)


def test_np_hash_fields_matches_device_hash():
    rng = np.random.default_rng(3)
    fields = tuple(rng.integers(0, 2 ** 32, 4096, dtype=np.uint32)
                   for _ in range(3))
    for salt in (*KEY_SALTS.values(), 0x7F4A7C15, row_salt(3, 2)):
        np.testing.assert_array_equal(
            np_hash_fields(fields, salt),
            np.asarray(hash_fields(tuple(map(jnp.asarray, fields)), salt)))


# ---------------------------------------------------------------------------
# scale: fixed memory under ~1M distinct flows, both deployment paths
# ---------------------------------------------------------------------------
def test_sketch_fixed_memory_million_distinct_flows():
    from repro.serving import DetectionEngine, DetectionService
    N = 1 << 20
    flows = _flow_trace(N)
    svc = DetectionService(epoch=8192, n_slots=1024,
                           state_backend="sketch", state_kw={"rows": 2})
    svc.observe_stream({k: v[:65536] for k, v in flows.items()}, chunk=32768)
    svc.fit(seed=0)
    idx, scores, alarms = svc.process_stream(
        {k: v[65536:] for k, v in flows.items()}, chunk=32768)
    assert len(idx) == (N - 65536) // 8192
    assert np.isfinite(scores).all()
    # memory stayed fixed: the tables are still (rows=2, width=1024)
    assert svc.state["uni"]["w"].shape[1:3] == (2, 1024)
    assert state_slots(svc.state) == 1024

    eng = DetectionEngine.from_service(svc, n_tenants=1, chunk=32768)
    t = eng.add_tenant()
    res = eng.run({t: flows})
    assert len(res[t][0]) == N // 8192
    assert np.isfinite(res[t][1]).all()
