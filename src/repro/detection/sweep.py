"""Evaluation sweep (Figures 1/7/14/15): AUC & F1 across sampling rates for
Peregrine (record sampling after FC) vs the Kitsune baseline (raw-packet
sampling before FC).

Faithful protocol (§5.2/§5.4): the detector is trained on the benign prefix
*as seen by the deployed system* — i.e. Peregrine trains on feature records
sampled 1:x, the baseline on the packet-sampled stream.  Feature computation
runs once per system/mode; per-rate work is slicing + KitNET training.
"""
from __future__ import annotations

from typing import Dict, Iterable, Tuple

import numpy as np

from repro.core import compute_features, default_backend, init_state
from repro.detection.md_backends import default_md_backend, score_records
from repro.core.records import epoch_indices
from repro.detection.kitnet import train_kitnet
from repro.detection.metrics import auc, f1_at_fpr
from repro.traffic.generator import to_jnp


def _fc(trace, n_slots, mode, state=None, backend=None,
        state_backend="dense", state_kw=None):
    st = state if state is not None else init_state(
        n_slots, state_backend=state_backend, **(state_kw or {}))
    pk = to_jnp(trace)
    if backend is None:
        backend = default_backend(mode)
    st, f = compute_features(st, pk, backend=backend, mode=mode)
    return st, np.asarray(f)


def sweep_attack(data: Dict, rates: Iterable[int], n_slots: int = 8192,
                 mode: str = "switch", seed: int = 0,
                 min_train_records: int = 16, backend: str = None,
                 md_backend: str = None, md_kw: Dict = None,
                 state_backend: str = "dense",
                 state_kw: Dict = None) -> Dict[str, Dict[int, Dict]]:
    """Returns {system: {rate: {auc, f1_10, f1_01, n_records, n_attack}}}.

    ``backend`` names the Peregrine FC implementation (serial/scan/pallas);
    ``md_backend`` the KitNET scoring implementation (einsum/pallas, with
    options in ``md_kw``), used for both systems.  ``state_backend``/
    ``state_kw`` pick the Peregrine flow-table layout (dense direct-indexed
    slots vs the Count-Min sketch) — the Kitsune baseline always computes
    exact software features over dense state, so a sketch sweep measures
    the accuracy cost of the compressed flow tables alone.
    """
    if md_backend is None:
        md_backend = default_md_backend()
    md_kw = md_kw or {}
    out = {"peregrine": {}, "kitsune": {}}

    # ---------------- Peregrine: FC over ALL packets, once ----------------
    st, f_train = _fc(data["train"], n_slots, mode, backend=backend,
                      state_backend=state_backend, state_kw=state_kw)
    _, f_eval = _fc(data["eval"], n_slots, mode, state=st, backend=backend)
    ev_labels = data["eval"]["label"]
    for rate in rates:
        tr_idx = epoch_indices(len(f_train), rate)
        if len(tr_idx) < min_train_records:  # keep detector trainable
            tr_idx = epoch_indices(len(f_train), max(1, len(f_train) //
                                                     min_train_records))
        net = train_kitnet(f_train[tr_idx], seed=seed,
                           md_backend=md_backend, md_kw=md_kw)
        ev_idx = epoch_indices(len(f_eval), rate)
        scores = score_records(net, f_eval[ev_idx], backend=md_backend,
                               **md_kw)
        labels = ev_labels[ev_idx]
        out["peregrine"][rate] = _metrics(scores, labels)

    # ---------------- Kitsune baseline: packet sampling -------------------
    n_tr = len(data["train"]["ts"])
    for rate in rates:
        tr_idx = epoch_indices(n_tr, rate)
        ev_idx = epoch_indices(len(data["eval"]["ts"]), rate, offset=n_tr)
        tr_s = {k: v[tr_idx] for k, v in data["train"].items()}
        ev_s = {k: v[ev_idx] for k, v in data["eval"].items()}
        st, f_tr = _fc(tr_s, n_slots, "exact")
        if len(f_tr) < 4:   # cannot even fit normalisation — classifier dead
            out["kitsune"][rate] = _metrics(
                np.zeros(max(len(ev_idx), 1)), ev_s["label"]
                if len(ev_idx) else np.array([0, 1], np.uint8))
            continue
        net = train_kitnet(f_tr, seed=seed, md_backend=md_backend,
                           md_kw=md_kw)
        _, f_ev = _fc(ev_s, n_slots, "exact", state=st)
        scores = score_records(net, f_ev, backend=md_backend, **md_kw)
        out["kitsune"][rate] = _metrics(scores, ev_s["label"])
    return out


def _metrics(scores: np.ndarray, labels: np.ndarray) -> Dict:
    return {
        "auc": auc(scores, labels),
        "f1_fpr10": f1_at_fpr(scores, labels, 0.1),
        "f1_fpr01": f1_at_fpr(scores, labels, 0.01),
        "n_records": int(len(labels)),
        "n_attack": int(np.asarray(labels).sum()),
    }
